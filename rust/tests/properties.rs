//! Cross-module property tests: system-level invariants that must hold
//! for every configuration, checked with the in-repo property runner
//! (`util::prop`) over randomized federations. Artifact-free (native
//! backend) so they run on any checkout.

use scale_fl::checkpoint::Checkpoint;
use scale_fl::config::{Partition, SimConfig};
use scale_fl::netsim::{MsgKind, SentMsg, TrafficLedger};
use scale_fl::quant::QuantVec;
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;
use scale_fl::topology::Topology;
use scale_fl::util::prop::{check, Config, Gen};
use scale_fl::util::rng::Rng;
use scale_fl::wire::{CodecKind, WireConfig};

fn random_cfg(g: &mut Gen) -> SimConfig {
    let n_nodes = g.usize_in(6, 36);
    let n_clusters = g.usize_in(2, n_nodes.min(6));
    let topo = match g.usize_in(0, 3) {
        0 => Topology::Ring,
        1 => Topology::KRegular(g.usize_in(2, 6)),
        2 => Topology::Full,
        _ => Topology::RandomK(g.usize_in(1, 4)),
    };
    // every system invariant must hold for lossy wire configs too
    let wire = match g.usize_in(0, 3) {
        0 => WireConfig::default(),
        1 => WireConfig { codec: CodecKind::F16, delta: false, topk: None },
        2 => WireConfig::preset("lean").unwrap(),
        _ => WireConfig { codec: CodecKind::I8, delta: true, topk: Some(1.0) },
    };
    SimConfig {
        wire,
        n_nodes,
        n_clusters,
        rounds: g.usize_in(2, 6),
        local_epochs: g.usize_in(1, 3),
        topology: topo,
        partition: if g.rng.chance(0.5) {
            Partition::Iid
        } else {
            Partition::LabelSkew(g.f64_in(0.2, 5.0))
        },
        checkpoint_min_delta: g.f64_in(0.0, 0.2),
        // partial participation must uphold every system invariant too
        sample_frac: if g.rng.chance(0.3) { g.f64_in(0.1, 1.0) } else { 1.0 },
        node_failure_prob: if g.rng.chance(0.3) { g.f64_in(0.0, 0.3) } else { 0.0 },
        quantize_exchange: g.rng.chance(0.3),
        secure_aggregation: g.rng.chance(0.3),
        dataset_samples: g.usize_in(150, 500),
        dataset_malignant: 0, // set below
        eval_every: 100,      // skip mid-run evals for speed
        seed: g.rng.next_u64(),
        ..Default::default()
    }
}

#[test]
fn sim_invariants_hold_across_random_configs() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    check(
        &Config { cases: 25, seed: 0xF00D, max_size: 8 },
        "sim invariants",
        |g| {
            let mut cfg = random_cfg(g);
            cfg.dataset_malignant = (cfg.dataset_samples as f64 * 0.37) as usize;
            let cfg = cfg.normalized();
            cfg.validate().map_err(|e| format!("cfg invalid: {e}"))?;
            let mut sim = Simulation::new(cfg.clone(), &compute)
                .map_err(|e| format!("setup: {e}"))?;
            let r = sim.run_scale().map_err(|e| format!("run: {e}"))?;

            // (1) cluster sizes partition the fleet
            let covered: usize = r.clusters.iter().map(|c| c.n_nodes).sum();
            if covered != cfg.n_nodes {
                return Err(format!("clusters cover {covered} != {}", cfg.n_nodes));
            }
            // (2) ledger GlobalUpdate count == per-cluster update totals
            let ledger_updates =
                r.ledger.get(&MsgKind::GlobalUpdate).map_or(0, |t| t.count);
            if ledger_updates != r.total_updates() {
                return Err(format!(
                    "ledger updates {ledger_updates} != report {}",
                    r.total_updates()
                ));
            }
            // (3) uploads bounded by driver-round opportunities, ≥ forced
            //     finals for clusters that were live at the end
            if r.total_updates() > (cfg.rounds * r.clusters.len()) as u64 {
                return Err("more uploads than driver-rounds".into());
            }
            // (4) every round's cumulative counter is monotone
            let mut prev = 0;
            for rec in &r.rounds {
                if rec.cum_updates < prev {
                    return Err("cum_updates not monotone".into());
                }
                prev = rec.cum_updates;
            }
            // (5) every cluster held ≥1 election (the initial one)
            if r.clusters.iter().any(|c| c.elections == 0) {
                return Err("cluster without initial election".into());
            }
            // (6) energies and latencies are non-negative and finite
            if !(r.comm_energy_j.is_finite() && r.comm_energy_j >= 0.0) {
                return Err("bad comm energy".into());
            }
            if r.rounds.iter().any(|x| !x.latency_ms.is_finite() || x.latency_ms < 0.0)
            {
                return Err("bad round latency".into());
            }
            // (7) metrics are probabilities
            let m = r.final_metrics;
            for (name, v) in [
                ("acc", m.accuracy),
                ("prec", m.precision),
                ("rec", m.recall),
                ("f1", m.f1),
                ("auc", m.roc_auc),
            ] {
                if !(0.0..=1.0).contains(&v) {
                    return Err(format!("{name} out of range: {v}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fedavg_updates_equal_live_node_rounds() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    check(
        &Config { cases: 15, seed: 0xBEEF, max_size: 8 },
        "fedavg accounting",
        |g| {
            let mut cfg = random_cfg(g);
            cfg.node_failure_prob = 0.0; // exact accounting without failures
            cfg.sample_frac = 1.0; // full participation: every node, every round
            cfg.dataset_malignant = (cfg.dataset_samples as f64 * 0.37) as usize;
            let cfg = cfg.normalized();
            let mut sim = Simulation::new(cfg.clone(), &compute)
                .map_err(|e| format!("setup: {e}"))?;
            let r = sim.run_fedavg(None).map_err(|e| format!("run: {e}"))?;
            let expect = (cfg.n_nodes * cfg.rounds) as u64;
            if r.total_updates() != expect {
                return Err(format!("updates {} != {expect}", r.total_updates()));
            }
            let broadcasts =
                r.ledger.get(&MsgKind::GlobalBroadcast).map_or(0, |t| t.count);
            if broadcasts != expect {
                return Err(format!("broadcasts {broadcasts} != {expect}"));
            }
            Ok(())
        },
    );
}

#[test]
fn checkpoint_codec_rejects_random_corruption() {
    check(
        &Config { cases: 150, seed: 0xC0DE, max_size: 64 },
        "checkpoint codec fuzz",
        |g| {
            let dim = g.usize_in(0, 600);
            let params: Vec<f32> = (0..dim).map(|_| g.rng.f32() * 10.0 - 5.0).collect();
            let cp = Checkpoint {
                round: g.rng.next_u64() as u32,
                metric: g.f64_in(0.0, 1.0),
                params,
            };
            let mut bytes = cp.to_bytes();
            // clean roundtrip first
            let back = Checkpoint::from_bytes(&bytes).map_err(|e| format!("{e}"))?;
            if back != cp {
                return Err("roundtrip mismatch".into());
            }
            // corrupt 1..4 random bytes: must error OR decode to an
            // identical checkpoint (a flip inside zlib padding may be
            // absorbed) — silent *different* data is the failure mode
            let flips = g.usize_in(1, 4);
            for _ in 0..flips {
                let i = g.rng.index(bytes.len());
                bytes[i] ^= (g.rng.next_u64() as u8) | 1;
            }
            match Checkpoint::from_bytes(&bytes) {
                Err(_) => Ok(()),
                Ok(decoded) if decoded == cp => Ok(()),
                Ok(_) => Err("corruption decoded silently to different data".into()),
            }
        },
    );
}

#[test]
fn quantization_never_exceeds_half_step_error() {
    check(
        &Config { cases: 200, seed: 0x0AB1, max_size: 128 },
        "quant bound",
        |g| {
            let xs: Vec<f32> = g.vec_of(|r| (r.f32() - 0.5) * r.f32() * 100.0);
            let q = QuantVec::encode(&xs);
            let back = q.decode();
            let bound = q.max_error() as f64 + 1e-5;
            for (a, b) in xs.iter().zip(&back) {
                if ((a - b).abs() as f64) > bound {
                    return Err(format!("{a} vs {b} bound {bound}"));
                }
            }
            // serialized form parses back to the same struct
            if QuantVec::from_bytes(&q.to_bytes()).as_ref() != Some(&q) {
                return Err("bytes roundtrip".into());
            }
            Ok(())
        },
    );
}

#[test]
fn full_run_is_bit_deterministic() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    check(
        &Config { cases: 6, seed: 0xD17E, max_size: 4 },
        "determinism",
        |g| {
            let mut cfg = random_cfg(g);
            cfg.dataset_malignant = (cfg.dataset_samples as f64 * 0.37) as usize;
            let cfg = cfg.normalized();
            let run = || {
                let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
                let r = sim.run_scale().unwrap();
                (
                    r.total_updates(),
                    r.final_metrics,
                    r.comm_energy_j,
                    r.ledger.get(&MsgKind::PeerExchange).map_or(0, |t| t.count),
                )
            };
            let (a, b) = (run(), run());
            if a != b {
                return Err(format!("two runs diverged: {a:?} vs {b:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn seeds_produce_distinct_but_valid_runs() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let mut rng = Rng::new(77);
    let mut outcomes = Vec::new();
    for _ in 0..4 {
        let cfg = SimConfig {
            n_nodes: 20,
            n_clusters: 4,
            rounds: 5,
            dataset_samples: 300,
            dataset_malignant: 110,
            eval_every: 5,
            seed: rng.next_u64(),
            ..Default::default()
        }
        .normalized();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        outcomes.push((r.total_updates(), r.comm_energy_j.to_bits()));
    }
    // different seeds should not all collapse to one trajectory
    let mut unique = outcomes.clone();
    unique.sort();
    unique.dedup();
    assert!(unique.len() >= 2, "seeds produced identical runs: {outcomes:?}");
}

#[test]
fn scenario_runs_are_byte_identical_given_config_and_seed() {
    // The determinism contract extended to active churn scenarios: the
    // same (config, seed, scenario) must yield byte-identical RunReports
    // (fingerprint == canonical JSON minus wall-clock).
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let scenario = scale_fl::scenario::Scenario::from_toml(
        "[regulation]\nmin_live_frac = 0.7\ncooldown = 1\n\
         [[event]]\nround = 1\nkind = \"leave\"\nfrac = 0.3\nduration = 2\n\
         [[event]]\nround = 2\nkind = \"bandwidth\"\nfactor = 0.5\nduration = 2\n\
         [[event]]\nround = 3\nkind = \"drift\"\nfrac = 0.2\nflip_frac = 0.3\n",
    )
    .unwrap();
    check(
        &Config { cases: 8, seed: 0xD0_0D, max_size: 8 },
        "scenario determinism",
        |g| {
            let mut cfg = random_cfg(g);
            cfg.dataset_malignant = (cfg.dataset_samples as f64 * 0.37) as usize;
            cfg.rounds = cfg.rounds.max(5); // let every event fire
            let cfg = cfg.normalized();
            let run = || {
                let mut sim = Simulation::new(cfg.clone(), &compute)
                    .map_err(|e| format!("setup: {e}"))?;
                let rep = sim
                    .run_scale_scenario(&scenario)
                    .map_err(|e| format!("run: {e}"))?;
                Ok::<String, String>(rep.fingerprint())
            };
            let (a, b) = (run()?, run()?);
            if a != b {
                return Err("two scenario runs diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn parallel_engines_match_sequential_fingerprints() {
    // The cluster-parallel determinism contract over *random* configs:
    // for any (config, seed), `threads ∈ {2, 4, 8}` must produce the
    // exact fingerprint of the sequential run — for SCALE and for the
    // sharded baseline phases.
    let compute = NativeSvm::new(NativeSvm::default_dims());
    check(
        &Config { cases: 4, seed: 0x9A11E1, max_size: 8 },
        "parallel determinism",
        |g| {
            let mut cfg = random_cfg(g);
            cfg.dataset_malignant = (cfg.dataset_samples as f64 * 0.37) as usize;
            let cfg = cfg.normalized();
            let scale_fp = |threads: usize| -> Result<String, String> {
                let mut c = cfg.clone();
                c.threads = threads;
                let mut sim = Simulation::new_parallel(c, &compute)
                    .map_err(|e| format!("setup: {e}"))?;
                Ok(sim.run_scale().map_err(|e| format!("run: {e}"))?.fingerprint())
            };
            let base = scale_fp(1)?;
            for threads in [2usize, 4, 8] {
                if scale_fp(threads)? != base {
                    return Err(format!("scale diverged at threads={threads}"));
                }
            }
            let baseline_fp = |threads: usize| -> Result<(String, String), String> {
                let mut c = cfg.clone();
                c.threads = threads;
                let mut sim = Simulation::new_parallel(c.clone(), &compute)
                    .map_err(|e| format!("setup: {e}"))?;
                let fedavg = sim
                    .run_fedavg(None)
                    .map_err(|e| format!("fedavg: {e}"))?
                    .fingerprint();
                let mut sim = Simulation::new_parallel(c, &compute)
                    .map_err(|e| format!("setup: {e}"))?;
                let hfl =
                    sim.run_hfl(2).map_err(|e| format!("hfl: {e}"))?.fingerprint();
                Ok((fedavg, hfl))
            };
            if baseline_fp(1)? != baseline_fp(4)? {
                return Err("baselines diverged at threads=4".into());
            }
            Ok(())
        },
    );
}

#[test]
fn traffic_ledger_merge_ordered_exact_and_order_insensitive() {
    // The round barrier's correctness conditions: (a) an in-order merge
    // of contiguous sub-ledgers reproduces the sequential ledger — the
    // message log byte-for-byte, u64 totals exactly, f64 totals to
    // rounding; (b) per-kind totals are associative and merge-order
    // insensitive (counts/bytes exact, f64 within float tolerance).
    check(
        &Config { cases: 40, seed: 0x1ED63, max_size: 64 },
        "ledger merge",
        |g| {
            let kinds = [
                MsgKind::Summary,
                MsgKind::PeerExchange,
                MsgKind::GlobalUpdate,
                MsgKind::Heartbeat,
                MsgKind::DriverCollect,
            ];
            let n = g.usize_in(1, 120);
            let msgs: Vec<SentMsg> = (0..n)
                .map(|i| SentMsg {
                    kind: kinds[g.rng.index(kinds.len())],
                    from: Some(g.rng.index(30)),
                    to: if g.rng.chance(0.2) { None } else { Some(g.rng.index(30)) },
                    bytes: g.rng.index(100_000) as u64,
                    latency_ms: g.f64_in(0.01, 500.0),
                    energy_j: g.f64_in(0.0, 5.0),
                    round: i % 7,
                })
                .collect();

            // sequential reference
            let mut seq = TrafficLedger::new(true);
            for m in &msgs {
                seq.record(m.clone());
            }

            // contiguous split, merged in order — the engine's barrier
            let cut1 = g.rng.index(n + 1);
            let cut2 = cut1 + g.rng.index(n - cut1 + 1);
            let mut parts: Vec<TrafficLedger> = Vec::new();
            for range in [0..cut1, cut1..cut2, cut2..n] {
                let mut l = TrafficLedger::new(true);
                for m in &msgs[range] {
                    l.record(m.clone());
                }
                parts.push(l);
            }
            let mut merged = TrafficLedger::new(true);
            for p in &parts {
                merged.merge(p);
            }
            if merged.log() != seq.log() {
                return Err("ordered merge log != sequential log".into());
            }
            if merged.global_updates_by_round() != seq.global_updates_by_round() {
                return Err("per-round update series mismatch".into());
            }
            for kind in kinds {
                let (a, b) = (merged.totals(kind), seq.totals(kind));
                if a.count != b.count || a.bytes != b.bytes {
                    return Err(format!("{kind:?} count/bytes mismatch"));
                }
                if (a.latency_ms - b.latency_ms).abs()
                    > 1e-9 * (1.0 + b.latency_ms.abs())
                    || (a.energy_j - b.energy_j).abs() > 1e-9 * (1.0 + b.energy_j.abs())
                {
                    return Err(format!("{kind:?} f64 totals drifted"));
                }
            }

            // associativity / order-insensitivity of per-kind totals
            let mut reversed = TrafficLedger::new(false);
            for p in parts.iter().rev() {
                reversed.merge(p);
            }
            let mut left = TrafficLedger::new(false);
            left.merge(&parts[0]);
            left.merge(&parts[1]);
            let mut nested = TrafficLedger::new(false);
            nested.merge(&left);
            nested.merge(&parts[2]);
            for kind in kinds {
                let s = seq.totals(kind);
                for (tag, l) in [("reversed", &reversed), ("nested", &nested)] {
                    let t = l.totals(kind);
                    if t.count != s.count || t.bytes != s.bytes {
                        return Err(format!(
                            "{tag} {kind:?} count/bytes not order-insensitive"
                        ));
                    }
                    if (t.latency_ms - s.latency_ms).abs()
                        > 1e-6 * (1.0 + s.latency_ms.abs())
                        || (t.energy_j - s.energy_j).abs()
                            > 1e-6 * (1.0 + s.energy_j.abs())
                    {
                        return Err(format!("{tag} {kind:?} f64 totals drifted"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn netsim_ledger_totals_match_per_message_sums() {
    // Payload accounting: with the full message log retained, per-kind
    // aggregate (count, bytes, latency, energy) must equal the sums over
    // the individual messages.
    use scale_fl::devices::{generate_fleet, FleetConfig};
    use scale_fl::netsim::{NetConfig, Network};

    check(
        &Config { cases: 40, seed: 0x1ED6E2, max_size: 64 },
        "netsim accounting",
        |g| {
            let fleet = generate_fleet(&FleetConfig {
                n_devices: 12,
                n_metros: 3,
                ..Default::default()
            });
            let mut net = Network::new(NetConfig::default(), g.rng.next_u64(), true);
            let kinds = [
                MsgKind::Summary,
                MsgKind::PeerExchange,
                MsgKind::DriverCollect,
                MsgKind::GlobalUpdate,
                MsgKind::Heartbeat,
                MsgKind::CheckpointLocal,
            ];
            let n_msgs = g.usize_in(1, 200);
            for round in 0..n_msgs {
                let kind = kinds[g.rng.index(kinds.len())];
                let from = g.rng.index(fleet.len());
                let to = g.rng.index(fleet.len());
                let bytes = g.rng.index(100_000) as u64;
                // mix in cloud endpoints (None) on both sides
                let fd = (from % 4 != 0).then_some(&fleet[from]);
                let td = (to % 5 != 0).then_some(&fleet[to]);
                if g.rng.chance(0.15) {
                    // window some sends under bandwidth degradation
                    net.set_bandwidth_degradation(g.f64_in(0.1, 1.0));
                }
                net.send(kind, fd, td, bytes, round % 7);
            }
            let log = net.ledger.log().to_vec();
            if log.len() != n_msgs {
                return Err(format!("log kept {} of {n_msgs}", log.len()));
            }
            for kind in kinds {
                let t = net.ledger.totals(kind);
                let count = log.iter().filter(|m| m.kind == kind).count() as u64;
                let bytes: u64 =
                    log.iter().filter(|m| m.kind == kind).map(|m| m.bytes).sum();
                let latency: f64 =
                    log.iter().filter(|m| m.kind == kind).map(|m| m.latency_ms).sum();
                let energy: f64 =
                    log.iter().filter(|m| m.kind == kind).map(|m| m.energy_j).sum();
                if t.count != count || t.bytes != bytes {
                    return Err(format!(
                        "{kind:?}: totals ({}, {}) != log sums ({count}, {bytes})",
                        t.count, t.bytes
                    ));
                }
                if (t.latency_ms - latency).abs() > 1e-9 * (1.0 + latency.abs()) {
                    return Err(format!("{kind:?}: latency {} != {latency}", t.latency_ms));
                }
                if (t.energy_j - energy).abs() > 1e-9 * (1.0 + energy.abs()) {
                    return Err(format!("{kind:?}: energy {} != {energy}", t.energy_j));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fleet_scale_label_skew_tiny_alpha_never_panics() {
    // Zero-sample clients: Dirichlet label-skew at fleet scale with tiny
    // α hands some nodes 0–2 rows (the steal pass can only guarantee a
    // row while donors exist), so empty train partitions and empty
    // per-node test splits flow through training, cluster eval,
    // pos_frac and the global hold-out union. The whole path must stay
    // panic-free and report finite, in-range metrics — with partial
    // participation layered on top.
    let compute = NativeSvm::new(NativeSvm::default_dims());
    for (seed, frac, rounds) in [(3u64, 1.0f64, 1usize), (11, 0.25, 2)] {
        let mut cfg = SimConfig::preset("fleet-4k").expect("fleet-4k preset");
        cfg.rounds = rounds;
        cfg.local_epochs = 1;
        cfg.partition = Partition::LabelSkew(0.05);
        cfg.sample_frac = frac;
        cfg.seed = seed;
        // debug-build friendliness (tier-1 runs unoptimized): skip the
        // greedy rebalance and cap Lloyd iterations, like fleet-100k
        cfg.cluster.balance_slack = None;
        cfg.cluster.max_iters = 12;
        let cfg = cfg.normalized();
        cfg.validate().expect("fleet cfg valid");
        let mut sim =
            Simulation::new_parallel(cfg.clone(), &compute).expect("fleet setup");
        let r = sim.run_scale().expect("fleet run");
        assert_eq!(r.rounds.len(), rounds, "seed {seed}");
        let covered: usize = r.clusters.iter().map(|c| c.n_nodes).sum();
        assert_eq!(covered, cfg.n_nodes);
        assert!(r.total_updates() >= 1);
        let m = r.final_metrics;
        for v in [m.accuracy, m.precision, m.recall, m.f1, m.roc_auc] {
            assert!((0.0..=1.0).contains(&v), "metric out of range: {v}");
        }
        // per-cluster rows stay sane even where members hold no data
        assert!(r
            .clusters
            .iter()
            .all(|c| (0.0..=1.0).contains(&c.final_accuracy)));
    }
}

#[test]
fn obs_shard_merge_totals_are_order_independent() {
    // The telemetry registry merges per-unit shards at the round
    // barrier in unit order; determinism of the *aggregates* rests on
    // every field being a pure sum. Fold a random batch of shards in
    // unit order and in reverse (and in two halves) — identical totals.
    use scale_fl::obs::{Counter, Shard};
    check(
        &Config { cases: 50, seed: 0x0B5, max_size: 8 },
        "obs shard merge order",
        |g| {
            let n_shards = g.usize_in(1, 12);
            let phases = ["train", "exchange", "collect", "upload", "broadcast"];
            let mut shards: Vec<Shard> = Vec::with_capacity(n_shards);
            for _ in 0..n_shards {
                let mut s = Shard::default();
                for &c in Counter::ALL.iter() {
                    s.bump(c, g.rng.next_u64() % 1000);
                }
                for _ in 0..g.usize_in(0, 6) {
                    let p = phases[g.usize_in(0, phases.len() - 1)];
                    s.record_span(p.to_string(), g.rng.next_u64() % 1_000_000);
                }
                shards.push(s);
            }
            let fold = |order: &[usize]| {
                let mut acc = Shard::default();
                for &i in order {
                    acc.absorb(&shards[i]);
                }
                acc
            };
            let forward: Vec<usize> = (0..n_shards).collect();
            let reverse: Vec<usize> = (0..n_shards).rev().collect();
            // split merge: halves folded separately, then combined —
            // the shape a tree-reduction barrier would produce
            let mid = n_shards / 2;
            let mut split = fold(&forward[..mid]);
            split.absorb(&fold(&forward[mid..]));
            let a = fold(&forward);
            if a != fold(&reverse) {
                return Err("reverse merge diverged".to_string());
            }
            if a != split {
                return Err("split merge diverged".to_string());
            }
            Ok(())
        },
    );
}
