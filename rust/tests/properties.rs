//! Cross-module property tests: system-level invariants that must hold
//! for every configuration, checked with the in-repo property runner
//! (`util::prop`) over randomized federations. Artifact-free (native
//! backend) so they run on any checkout.

use scale_fl::checkpoint::Checkpoint;
use scale_fl::config::{Partition, SimConfig};
use scale_fl::netsim::MsgKind;
use scale_fl::quant::QuantVec;
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;
use scale_fl::topology::Topology;
use scale_fl::util::prop::{check, Config, Gen};
use scale_fl::util::rng::Rng;

fn random_cfg(g: &mut Gen) -> SimConfig {
    let n_nodes = g.usize_in(6, 36);
    let n_clusters = g.usize_in(2, n_nodes.min(6));
    let topo = match g.usize_in(0, 3) {
        0 => Topology::Ring,
        1 => Topology::KRegular(g.usize_in(2, 6)),
        2 => Topology::Full,
        _ => Topology::RandomK(g.usize_in(1, 4)),
    };
    SimConfig {
        n_nodes,
        n_clusters,
        rounds: g.usize_in(2, 6),
        local_epochs: g.usize_in(1, 3),
        topology: topo,
        partition: if g.rng.chance(0.5) {
            Partition::Iid
        } else {
            Partition::LabelSkew(g.f64_in(0.2, 5.0))
        },
        checkpoint_min_delta: g.f64_in(0.0, 0.2),
        node_failure_prob: if g.rng.chance(0.3) { g.f64_in(0.0, 0.3) } else { 0.0 },
        quantize_exchange: g.rng.chance(0.3),
        secure_aggregation: g.rng.chance(0.3),
        dataset_samples: g.usize_in(150, 500),
        dataset_malignant: 0, // set below
        eval_every: 100,      // skip mid-run evals for speed
        seed: g.rng.next_u64(),
        ..Default::default()
    }
}

#[test]
fn sim_invariants_hold_across_random_configs() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    check(
        &Config { cases: 25, seed: 0xF00D, max_size: 8 },
        "sim invariants",
        |g| {
            let mut cfg = random_cfg(g);
            cfg.dataset_malignant = (cfg.dataset_samples as f64 * 0.37) as usize;
            let cfg = cfg.normalized();
            cfg.validate().map_err(|e| format!("cfg invalid: {e}"))?;
            let mut sim = Simulation::new(cfg.clone(), &compute)
                .map_err(|e| format!("setup: {e}"))?;
            let r = sim.run_scale().map_err(|e| format!("run: {e}"))?;

            // (1) cluster sizes partition the fleet
            let covered: usize = r.clusters.iter().map(|c| c.n_nodes).sum();
            if covered != cfg.n_nodes {
                return Err(format!("clusters cover {covered} != {}", cfg.n_nodes));
            }
            // (2) ledger GlobalUpdate count == per-cluster update totals
            let ledger_updates =
                r.ledger.get(&MsgKind::GlobalUpdate).map_or(0, |t| t.count);
            if ledger_updates != r.total_updates() {
                return Err(format!(
                    "ledger updates {ledger_updates} != report {}",
                    r.total_updates()
                ));
            }
            // (3) uploads bounded by driver-round opportunities, ≥ forced
            //     finals for clusters that were live at the end
            if r.total_updates() > (cfg.rounds * r.clusters.len()) as u64 {
                return Err("more uploads than driver-rounds".into());
            }
            // (4) every round's cumulative counter is monotone
            let mut prev = 0;
            for rec in &r.rounds {
                if rec.cum_updates < prev {
                    return Err("cum_updates not monotone".into());
                }
                prev = rec.cum_updates;
            }
            // (5) every cluster held ≥1 election (the initial one)
            if r.clusters.iter().any(|c| c.elections == 0) {
                return Err("cluster without initial election".into());
            }
            // (6) energies and latencies are non-negative and finite
            if !(r.comm_energy_j.is_finite() && r.comm_energy_j >= 0.0) {
                return Err("bad comm energy".into());
            }
            if r.rounds.iter().any(|x| !x.latency_ms.is_finite() || x.latency_ms < 0.0)
            {
                return Err("bad round latency".into());
            }
            // (7) metrics are probabilities
            let m = r.final_metrics;
            for (name, v) in [
                ("acc", m.accuracy),
                ("prec", m.precision),
                ("rec", m.recall),
                ("f1", m.f1),
                ("auc", m.roc_auc),
            ] {
                if !(0.0..=1.0).contains(&v) {
                    return Err(format!("{name} out of range: {v}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fedavg_updates_equal_live_node_rounds() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    check(
        &Config { cases: 15, seed: 0xBEEF, max_size: 8 },
        "fedavg accounting",
        |g| {
            let mut cfg = random_cfg(g);
            cfg.node_failure_prob = 0.0; // exact accounting without failures
            cfg.dataset_malignant = (cfg.dataset_samples as f64 * 0.37) as usize;
            let cfg = cfg.normalized();
            let mut sim = Simulation::new(cfg.clone(), &compute)
                .map_err(|e| format!("setup: {e}"))?;
            let r = sim.run_fedavg(None).map_err(|e| format!("run: {e}"))?;
            let expect = (cfg.n_nodes * cfg.rounds) as u64;
            if r.total_updates() != expect {
                return Err(format!("updates {} != {expect}", r.total_updates()));
            }
            let broadcasts =
                r.ledger.get(&MsgKind::GlobalBroadcast).map_or(0, |t| t.count);
            if broadcasts != expect {
                return Err(format!("broadcasts {broadcasts} != {expect}"));
            }
            Ok(())
        },
    );
}

#[test]
fn checkpoint_codec_rejects_random_corruption() {
    check(
        &Config { cases: 150, seed: 0xC0DE, max_size: 64 },
        "checkpoint codec fuzz",
        |g| {
            let dim = g.usize_in(0, 600);
            let params: Vec<f32> = (0..dim).map(|_| g.rng.f32() * 10.0 - 5.0).collect();
            let cp = Checkpoint {
                round: g.rng.next_u64() as u32,
                metric: g.f64_in(0.0, 1.0),
                params,
            };
            let mut bytes = cp.to_bytes();
            // clean roundtrip first
            let back = Checkpoint::from_bytes(&bytes).map_err(|e| format!("{e}"))?;
            if back != cp {
                return Err("roundtrip mismatch".into());
            }
            // corrupt 1..4 random bytes: must error OR decode to an
            // identical checkpoint (a flip inside zlib padding may be
            // absorbed) — silent *different* data is the failure mode
            let flips = g.usize_in(1, 4);
            for _ in 0..flips {
                let i = g.rng.index(bytes.len());
                bytes[i] ^= (g.rng.next_u64() as u8) | 1;
            }
            match Checkpoint::from_bytes(&bytes) {
                Err(_) => Ok(()),
                Ok(decoded) if decoded == cp => Ok(()),
                Ok(_) => Err("corruption decoded silently to different data".into()),
            }
        },
    );
}

#[test]
fn quantization_never_exceeds_half_step_error() {
    check(
        &Config { cases: 200, seed: 0x0AB1, max_size: 128 },
        "quant bound",
        |g| {
            let xs: Vec<f32> = g.vec_of(|r| (r.f32() - 0.5) * r.f32() * 100.0);
            let q = QuantVec::encode(&xs);
            let back = q.decode();
            let bound = q.max_error() as f64 + 1e-5;
            for (a, b) in xs.iter().zip(&back) {
                if ((a - b).abs() as f64) > bound {
                    return Err(format!("{a} vs {b} bound {bound}"));
                }
            }
            // serialized form parses back to the same struct
            if QuantVec::from_bytes(&q.to_bytes()).as_ref() != Some(&q) {
                return Err("bytes roundtrip".into());
            }
            Ok(())
        },
    );
}

#[test]
fn full_run_is_bit_deterministic() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    check(
        &Config { cases: 6, seed: 0xD17E, max_size: 4 },
        "determinism",
        |g| {
            let mut cfg = random_cfg(g);
            cfg.dataset_malignant = (cfg.dataset_samples as f64 * 0.37) as usize;
            let cfg = cfg.normalized();
            let run = || {
                let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
                let r = sim.run_scale().unwrap();
                (
                    r.total_updates(),
                    r.final_metrics,
                    r.comm_energy_j,
                    r.ledger.get(&MsgKind::PeerExchange).map_or(0, |t| t.count),
                )
            };
            let (a, b) = (run(), run());
            if a != b {
                return Err(format!("two runs diverged: {a:?} vs {b:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn seeds_produce_distinct_but_valid_runs() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let mut rng = Rng::new(77);
    let mut outcomes = Vec::new();
    for _ in 0..4 {
        let cfg = SimConfig {
            n_nodes: 20,
            n_clusters: 4,
            rounds: 5,
            dataset_samples: 300,
            dataset_malignant: 110,
            eval_every: 5,
            seed: rng.next_u64(),
            ..Default::default()
        }
        .normalized();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        outcomes.push((r.total_updates(), r.comm_energy_j.to_bits()));
    }
    // different seeds should not all collapse to one trajectory
    let mut unique = outcomes.clone();
    unique.sort();
    unique.dedup();
    assert!(unique.len() >= 2, "seeds produced identical runs: {outcomes:?}");
}
