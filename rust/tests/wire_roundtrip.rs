//! Wire-protocol contract tests: codec round-trips stay within their
//! per-tensor error bounds, lossless runs reproduce the seed byte model
//! and fingerprints exactly, and the traffic ledger agrees with
//! `Frame::encoded_len` / `WireConfig::frame_bytes` to the byte.

use scale_fl::config::SimConfig;
use scale_fl::netsim::{param_payload_bytes, MsgKind};
use scale_fl::quant::{f16_from_f32, f16_to_f32, QuantVec};
use scale_fl::runtime::compute::{ModelCompute, NativeSvm};
use scale_fl::sim::Simulation;
use scale_fl::util::prop::{check, Config, Gen};
use scale_fl::wire::{codec, CodecKind, Frame, WireConfig};

fn gen_vec(g: &mut Gen) -> Vec<f32> {
    g.vec_of(|r| (r.f32() - 0.5) * r.f32() * 50.0)
}

#[test]
fn f32_passthrough_is_bit_exact_and_byte_compatible() {
    check(
        &Config { cases: 100, seed: 0x3132, max_size: 300 },
        "f32 passthrough",
        |g| {
            let xs = gen_vec(g);
            let wire = WireConfig::default();
            let frame = wire.encode(&xs, 0, None);
            if frame.encoded_len() != param_payload_bytes(xs.len()) {
                return Err(format!(
                    "frame {} != legacy {}",
                    frame.encoded_len(),
                    param_payload_bytes(xs.len())
                ));
            }
            let back = frame.decode(None).map_err(|e| e.to_string())?;
            for (a, b) in xs.iter().zip(&back) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("bit drift: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn i8_roundtrip_error_within_per_tensor_scale_bound() {
    check(
        &Config { cases: 150, seed: 0x18, max_size: 400 },
        "i8 scale bound",
        |g| {
            let xs = gen_vec(g);
            let bound = QuantVec::encode(&xs).max_error() as f64 + 1e-5;
            let back = codec(CodecKind::I8)
                .decode(&codec(CodecKind::I8).encode(&xs), xs.len())
                .map_err(|e| e.to_string())?;
            for (a, b) in xs.iter().zip(&back) {
                if ((a - b).abs() as f64) > bound {
                    return Err(format!("{a} vs {b} (bound {bound})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn f16_roundtrip_error_within_half_ulp_bound() {
    check(
        &Config { cases: 150, seed: 0xF16, max_size: 400 },
        "f16 bound",
        |g| {
            let xs = gen_vec(g);
            let back = codec(CodecKind::F16)
                .decode(&codec(CodecKind::F16).encode(&xs), xs.len())
                .map_err(|e| e.to_string())?;
            for (a, b) in xs.iter().zip(&back) {
                let bound = (a.abs() as f64 / 1024.0).max(1e-7);
                if ((a - b).abs() as f64) > bound {
                    return Err(format!("{a} vs {b} (bound {bound})"));
                }
            }
            // the codec is the f16_from/to pair elementwise
            if back
                .iter()
                .zip(&xs)
                .any(|(b, a)| b.to_bits() != f16_to_f32(f16_from_f32(*a)).to_bits())
            {
                return Err("codec disagrees with f16 primitives".into());
            }
            Ok(())
        },
    );
}

#[test]
fn delta_frames_roundtrip_and_serialize_across_random_configs() {
    check(
        &Config { cases: 120, seed: 0xDE17A, max_size: 200 },
        "delta frames",
        |g| {
            let base = gen_vec(g);
            let xs: Vec<f32> =
                base.iter().map(|b| b + (g.rng.f32() - 0.5) * 0.2).collect();
            let wire = WireConfig {
                codec: match g.usize_in(0, 2) {
                    0 => CodecKind::F32,
                    1 => CodecKind::F16,
                    _ => CodecKind::I8,
                },
                delta: true,
                topk: match g.usize_in(0, 2) {
                    0 => None,
                    1 => Some(g.f64_in(0.05, 0.9)),
                    _ => Some(1.0),
                },
            };
            let frame = wire.encode(&xs, 5, Some((4, &base)));
            // byte-accounting closed form matches the built frame
            if frame.encoded_len() != wire.frame_bytes(xs.len(), true) {
                return Err(format!(
                    "{:?}: encoded_len {} != frame_bytes {}",
                    wire,
                    frame.encoded_len(),
                    wire.frame_bytes(xs.len(), true)
                ));
            }
            // serialization round-trips
            let parsed = Frame::from_bytes(&frame.to_bytes()).map_err(|e| e.to_string())?;
            if parsed != frame {
                return Err("serialize/parse mismatch".into());
            }
            // decoding reproduces xs on the kept coordinates within the
            // codec bound; dropped coordinates fall back to the baseline
            let out = frame.decode(Some(&base)).map_err(|e| e.to_string())?;
            if out.len() != xs.len() {
                return Err("dim mismatch".into());
            }
            for (i, o) in out.iter().enumerate() {
                let to_x = (o - xs[i]).abs();
                let to_base = (o - base[i]).abs();
                // each decoded coord is near the true value or the baseline
                let slack = 0.5 + xs[i].abs() as f64 * 1e-2;
                if (to_x.min(to_base) as f64) > slack {
                    return Err(format!(
                        "coord {i}: {o} far from both {} and {}",
                        xs[i], base[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn lossless_run_fingerprint_matches_explicit_passthrough() {
    // the default config IS the passthrough; making it explicit (or
    // spelling it via the preset) must not move the fingerprint
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let run = |wire: WireConfig| {
        let mut cfg = SimConfig {
            n_nodes: 16,
            n_clusters: 4,
            rounds: 5,
            dataset_samples: 320,
            dataset_malignant: 120,
            eval_every: 5,
            seed: 9,
            ..Default::default()
        }
        .normalized();
        cfg.wire = wire;
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        sim.run_scale().unwrap().fingerprint()
    };
    let implicit = run(WireConfig::default());
    let explicit = run(WireConfig::preset("lossless").unwrap());
    assert_eq!(implicit, explicit);
}

#[test]
fn ledger_bytes_equal_frame_encoded_len_times_count() {
    // scenario-free run with the ring primed at formation: every param
    // transfer of a kind has the same frame size, so ledger totals must
    // factor exactly as count × encoded_len
    let compute = NativeSvm::new(NativeSvm::default_dims());
    for preset in ["lossless", "f16", "i8", "lean", "sparse"] {
        let wire = WireConfig::preset(preset).unwrap();
        let mut cfg = SimConfig {
            n_nodes: 18,
            n_clusters: 3,
            rounds: 5,
            dataset_samples: 360,
            dataset_malignant: 130,
            eval_every: 100,
            seed: 4,
            ..Default::default()
        }
        .normalized();
        cfg.wire = wire;
        let dim = compute.param_dim();
        // a representative frame built exactly like the exchange path
        let baseline = vec![0.0f32; dim];
        let xs = vec![0.1f32; dim];
        let frame = wire.encode(&xs, 1, Some((0, &baseline)));
        assert_eq!(frame.encoded_len(), wire.frame_bytes(dim, true), "{preset}");
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        for kind in
            [MsgKind::PeerExchange, MsgKind::DriverCollect, MsgKind::DriverBroadcast]
        {
            let t = r.ledger[&kind];
            assert_eq!(
                t.bytes,
                t.count * frame.encoded_len(),
                "{preset} {kind:?}"
            );
        }
    }
}
