//! Golden-fingerprint regression suite: pins `RunReport::fingerprint`
//! (as its 64-bit FNV hash) for canonical `(config, seed, scenario)`
//! triples — SCALE, FedAvg and HFL, scenario-free and under churn, all
//! through the unified `--algo` engine — so a refactor cannot silently
//! change results.
//!
//! Every case is executed twice — `--threads 1` and `SCALE_TEST_THREADS`
//! (default 4) — and the two fingerprints must match byte-for-byte
//! *before* the golden comparison: the cluster-parallel determinism
//! contract is checked on every run, golden file or not.
//!
//! Blessing: `SCALE_BLESS=1 cargo test --test golden_fingerprints`
//! regenerates `tests/golden/fingerprints.txt`. Entries missing from the
//! file (e.g. a freshly added case) are auto-primed on first run;
//! entries that *exist* and mismatch fail the suite.

mod common;

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

use scale_fl::config::{CheckpointMode, Partition, SimConfig};
use scale_fl::scenario::Scenario;
use scale_fl::sim::{AlgoKind, Simulation};

/// One golden triple: every case drives the unified engine through
/// `Simulation::run_algo`, optionally under a scenario timeline —
/// including the FedAvg/HFL-under-churn combinations the engine
/// refactor made possible.
struct Case {
    name: &'static str,
    cfg: SimConfig,
    algo: AlgoKind,
    scenario: Option<&'static str>,
}

fn base_cfg(nodes: usize, clusters: usize, rounds: usize, seed: u64) -> SimConfig {
    SimConfig {
        n_nodes: nodes,
        n_clusters: clusters,
        rounds,
        local_epochs: 2,
        eval_every: 4,
        dataset_samples: nodes * 18,
        dataset_malignant: nodes * 7,
        seed,
        ..Default::default()
    }
    .normalized()
}

const CHURN_SCENARIO: &str = "\
[regulation]\nmin_live_frac = 0.7\ncooldown = 1\n\
[[event]]\nround = 1\nkind = \"leave\"\nfrac = 0.3\nduration = 2\n\
[[event]]\nround = 3\nkind = \"bandwidth\"\nfactor = 0.5\nduration = 2\n\
[[event]]\nround = 4\nkind = \"drift\"\nfrac = 0.2\nflip_frac = 0.3\n";

fn cases() -> Vec<Case> {
    let case = |name, cfg, algo, scenario| Case { name, cfg, algo, scenario };
    let sampled = |nodes, clusters, rounds, seed| {
        let mut cfg = base_cfg(nodes, clusters, rounds, seed);
        cfg.sample_frac = 0.5;
        cfg.normalized()
    };
    let sample_one = {
        // sample_frac set explicitly to 1.0: must pin the SAME hash as
        // scale-iid-20x4 (the pre-sampling fingerprint) forever — the
        // byte-compatibility contract of the sampling axis, also
        // asserted in-test below
        let mut cfg = base_cfg(20, 4, 8, 5);
        cfg.sample_frac = 1.0;
        cfg.normalized()
    };
    let skew_quantized = {
        let mut cfg = base_cfg(24, 4, 8, 11);
        cfg.partition = Partition::LabelSkew(0.4);
        cfg.quantize_exchange = true;
        cfg.normalized()
    };
    let secagg_failures = {
        let mut cfg = base_cfg(20, 4, 10, 7);
        cfg.secure_aggregation = true;
        cfg.checkpoint_mode = CheckpointMode::Accuracy;
        cfg.node_failure_prob = 0.2;
        cfg.node_recovery_prob = 0.5;
        cfg.normalized()
    };
    let secagg_churn = {
        // masked collect + dropout recovery through the churn timeline:
        // pins the fixed-point masking path, the departure cohort draw
        // and the reveal-based unmasking in one triple
        let mut cfg = base_cfg(30, 5, 10, 13);
        cfg.secure_aggregation = true;
        cfg.normalized()
    };
    let wire_lean = {
        let mut cfg = base_cfg(20, 4, 8, 17);
        cfg.wire = scale_fl::wire::WireConfig::preset("lean").unwrap();
        cfg.normalized()
    };
    vec![
        case("scale-iid-20x4", base_cfg(20, 4, 8, 5), AlgoKind::Scale, None),
        case("scale-skew-quantized", skew_quantized, AlgoKind::Scale, None),
        case("scale-secagg-accgate-failures", secagg_failures, AlgoKind::Scale, None),
        case(
            "scale-secagg-churn",
            secagg_churn,
            AlgoKind::Scale,
            Some(CHURN_SCENARIO),
        ),
        case("scale-wire-lean", wire_lean, AlgoKind::Scale, None),
        case(
            "scale-scenario-churn",
            base_cfg(30, 5, 10, 13),
            AlgoKind::Scale,
            Some(CHURN_SCENARIO),
        ),
        case("fedavg-iid-20x4", base_cfg(20, 4, 6, 5), AlgoKind::FedAvg, None),
        case(
            "hfl-20x4-period3",
            base_cfg(20, 4, 8, 9),
            AlgoKind::Hfl { edge_period: 3 },
            None,
        ),
        // baselines under churn: newly possible once FedAvg/HFL run
        // through the scenario-aware unified engine
        case(
            "fedavg-scenario-churn",
            base_cfg(30, 5, 10, 13),
            AlgoKind::FedAvg,
            Some(CHURN_SCENARIO),
        ),
        case(
            "hfl-scenario-churn-period2",
            base_cfg(30, 5, 10, 19),
            AlgoKind::Hfl { edge_period: 2 },
            Some(CHURN_SCENARIO),
        ),
        // partial participation (PR 5): sample_frac = 1.0 must reproduce
        // the pre-sampling pins byte-for-byte; 0.5 pins the sampled path
        // for every algorithm
        case("scale-sample-1p0", sample_one, AlgoKind::Scale, None),
        case("scale-sample-0p5", sampled(20, 4, 8, 5), AlgoKind::Scale, None),
        case("fedavg-sample-0p5", sampled(20, 4, 6, 5), AlgoKind::FedAvg, None),
        case(
            "hfl-sample-0p5-period3",
            sampled(20, 4, 8, 9),
            AlgoKind::Hfl { edge_period: 3 },
            None,
        ),
    ]
}

fn run_case(case: &Case, threads: usize) -> (String, String) {
    let compute = common::native();
    let mut cfg = case.cfg.clone();
    cfg.threads = threads;
    let mut sim = Simulation::new_parallel(cfg, &compute).expect("sim setup");
    let scenario = match case.scenario {
        Some(toml) => Scenario::from_toml(toml).expect("scenario toml"),
        None => Scenario::none(),
    };
    let report = sim.run_algo(case.algo, &scenario).expect("run");
    (report.fingerprint(), report.fingerprint_hash())
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/fingerprints.txt")
}

fn read_golden() -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    let Ok(text) = std::fs::read_to_string(golden_path()) else {
        return out;
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((name, hash)) = line.split_once('=') {
            out.insert(name.trim().to_string(), hash.trim().to_string());
        }
    }
    out
}

fn write_golden(entries: &BTreeMap<String, String>) {
    let mut text = String::from(
        "# Golden RunReport fingerprint hashes (64-bit FNV of the canonical\n\
         # JSON, wall-clock excluded). One line per (config, seed, scenario)\n\
         # triple; regenerate intentionally with:\n\
         #   SCALE_BLESS=1 cargo test --test golden_fingerprints\n",
    );
    for (name, hash) in entries {
        let _ = writeln!(text, "{name} = {hash}");
    }
    let path = golden_path();
    std::fs::create_dir_all(path.parent().unwrap()).expect("golden dir");
    std::fs::write(&path, text).expect("writing golden file");
}

#[test]
fn golden_fingerprints_pinned_and_thread_invariant() {
    let bless = matches!(std::env::var("SCALE_BLESS").as_deref(), Ok("1"));
    // the arming guard (CI sets this): a golden file with zero pinned
    // entries is a hard failure instead of a silent bootstrap, so the
    // suite can never ship unprimed without CI going red
    let require_pinned =
        matches!(std::env::var("SCALE_REQUIRE_PINNED").as_deref(), Ok("1"));
    let par_threads: usize = std::env::var("SCALE_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);

    let mut golden = read_golden();
    let armed_at_start = !golden.is_empty();
    let mut mismatches: Vec<String> = Vec::new();
    let mut primed = false;
    let mut computed: BTreeMap<&'static str, String> = BTreeMap::new();

    for case in cases() {
        let name = case.name;
        let (fp_seq, hash_seq) = run_case(&case, 1);
        if par_threads > 1 {
            let (fp_par, _) = run_case(&case, par_threads);
            assert_eq!(
                fp_seq, fp_par,
                "{name}: fingerprint diverged between threads 1 and {par_threads}"
            );
        }
        // telemetry must be write-only with respect to the simulation:
        // the same case under a fully live registry (spans + counters;
        // no sinks) reproduces the bare fingerprint at both thread
        // counts — the obs-on/obs-off identity the subsystem pins
        for threads in [1, par_threads] {
            scale_fl::obs::install(&scale_fl::obs::ObsConfig {
                enabled: true,
                ..Default::default()
            })
            .expect("obs install");
            let (fp_obs, _) = run_case(&case, threads);
            scale_fl::obs::finish().expect("obs finish");
            assert_eq!(
                fp_seq, fp_obs,
                "{name}: telemetry moved the fingerprint at threads {threads}"
            );
        }
        computed.insert(name, hash_seq.clone());
        match golden.get(name) {
            Some(stored) if *stored == hash_seq => {}
            Some(stored) => {
                if bless {
                    golden.insert(name.to_string(), hash_seq.clone());
                    primed = true;
                } else {
                    mismatches.push(format!(
                        "{name}: stored {stored}, computed {hash_seq}"
                    ));
                }
            }
            None => {
                // auto-prime fresh cases so the suite bootstraps itself
                // in environments without a committed pin — loudly: an
                // unprimed case verifies thread-invariance but pins
                // NOTHING until the regenerated file is committed
                eprintln!(
                    "golden_fingerprints: priming '{name}' = {hash_seq} \
                     (no stored pin — commit tests/golden/fingerprints.txt \
                     to arm the regression check)"
                );
                golden.insert(name.to_string(), hash_seq.clone());
                primed = true;
            }
        }
    }

    // sample_frac = 1.0 is the pre-sampling engine byte-for-byte: the
    // explicit-1.0 case must hash identically to the default-config case
    // whatever the pins say (this holds even before the file is armed)
    assert_eq!(
        computed["scale-sample-1p0"], computed["scale-iid-20x4"],
        "sample_frac = 1.0 must not move the fingerprint"
    );

    if primed {
        write_golden(&golden);
    }
    assert!(
        mismatches.is_empty(),
        "golden fingerprints changed (rerun with SCALE_BLESS=1 only if the \
         change is intentional):\n{}",
        mismatches.join("\n")
    );
    assert!(
        armed_at_start || !require_pinned,
        "tests/golden/fingerprints.txt contained NO pinned entries — the \
         regression gate was unarmed. The suite has now written a freshly \
         primed file (or run `bash tools/arm_goldens.sh`); commit it to arm \
         the gate, then re-run."
    );
}
