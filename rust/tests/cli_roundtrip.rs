//! CLI integration: the `scale scenario gen → run → sweep --verify`
//! round-trip through the real binary, asserting exit codes, that the
//! printed re-clustering timeline parses, and that the JSON report is
//! valid. Exercises `--threads` end-to-end on the way.

use std::path::PathBuf;
use std::process::{Command, Output};

fn scale_bin() -> &'static str {
    env!("CARGO_BIN_EXE_scale")
}

fn run(args: &[&str]) -> Output {
    Command::new(scale_bin())
        .args(args)
        .output()
        .expect("spawning scale binary")
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("scale_cli_rt_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir
}

#[test]
fn scenario_gen_run_sweep_roundtrip() {
    let dir = temp_dir("scenario");
    let toml = dir.join("scenario.toml");
    let report = dir.join("report.json");

    // --- gen ---
    let out = run(&["scenario", "gen", "--out", toml.to_str().unwrap()]);
    assert!(out.status.success(), "gen failed: {out:?}");
    assert!(toml.exists(), "scenario file not written");

    // --- run (threads=2 exercises the parallel engine end-to-end) ---
    let out = run(&[
        "scenario",
        "run",
        "--file",
        toml.to_str().unwrap(),
        "--threads",
        "2",
        "--out",
        report.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "run failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);

    // the printed self-regulation timeline must parse: a header then one
    // `round | events | reclu | elect | live` row per round
    let mut lines = stdout.lines();
    lines
        .find(|l| l.contains("round | events | reclu | elect | live"))
        .expect("timeline header missing");
    let mut rows = 0usize;
    for line in lines.by_ref() {
        let cols: Vec<&str> = line.split('|').map(str::trim).collect();
        if cols.len() != 5 {
            break; // end of the table
        }
        for c in &cols {
            c.parse::<u64>()
                .unwrap_or_else(|_| panic!("non-numeric timeline cell '{c}' in '{line}'"));
        }
        rows += 1;
    }
    // the example scenario's [sim] table runs 15 rounds
    assert_eq!(rows, 15, "timeline rows:\n{stdout}");
    assert!(stdout.contains("re-clusterings"), "{stdout}");

    // the JSON report parses and carries the scenario log
    let json = std::fs::read_to_string(&report).expect("report.json");
    let v = scale_fl::util::json::parse(&json).expect("report JSON parses");
    assert_eq!(
        v.get("rounds").and_then(|r| r.as_arr()).map(|a| a.len()),
        Some(15),
        "report rounds"
    );
    assert!(v.get("scenario").is_some(), "scenario log missing");

    // --- sweep --verify: parallel must equal sequential, and say so ---
    let out = run(&[
        "scenario",
        "sweep",
        "--file",
        toml.to_str().unwrap(),
        "--seeds",
        "2",
        "--verify",
    ]);
    assert!(out.status.success(), "sweep failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("verify: parallel == sequential"),
        "verify line missing:\n{stdout}"
    );

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_run_algo_baselines_end_to_end_with_thread_parity() {
    // the unified --algo axis through the real binary: FedAvg and HFL
    // execute the generated churn scenario end-to-end, and the printed
    // fingerprint hash is identical for --threads 1 and --threads 4
    let dir = temp_dir("algo");
    let toml = dir.join("scenario.toml");
    let out = run(&["scenario", "gen", "--out", toml.to_str().unwrap()]);
    assert!(out.status.success(), "gen failed: {out:?}");

    for algo in ["fedavg", "hfl"] {
        let fingerprint = |threads: &str| -> String {
            let out = run(&[
                "scenario",
                "run",
                "--file",
                toml.to_str().unwrap(),
                "--algo",
                algo,
                "--threads",
                threads,
            ]);
            assert!(out.status.success(), "--algo {algo} --threads {threads}: {out:?}");
            let stdout = String::from_utf8_lossy(&out.stdout);
            assert!(stdout.contains(&format!("[{algo}]")), "{stdout}");
            assert!(stdout.contains(&format!("=== {algo} run ===")), "{stdout}");
            stdout
                .lines()
                .find(|l| l.starts_with("fingerprint"))
                .unwrap_or_else(|| panic!("no fingerprint line:\n{stdout}"))
                .to_string()
        };
        assert_eq!(
            fingerprint("1"),
            fingerprint("4"),
            "--algo {algo} diverged between threads 1 and 4"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_matrix_writes_one_row_per_cell() {
    let dir = temp_dir("matrix");
    let csv = dir.join("matrix.csv");
    // a deliberately tiny grid: paper preset shrunk to 12 nodes / 2
    // rounds, one codec axis entry, all three algorithms
    let out = run(&[
        "bench",
        "matrix",
        "--presets",
        "paper",
        "--codecs",
        "lean",
        "--nodes",
        "12",
        "--clusters",
        "3",
        "--rounds",
        "2",
        "--epochs",
        "1",
        "--threads",
        "2",
        "--csv",
        csv.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "bench matrix failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("3 cell(s)"), "{stdout}");
    let text = std::fs::read_to_string(&csv).expect("csv written");
    assert!(text.starts_with("nodes,clusters,rounds,threads"), "{text}");
    // header + one row per algorithm
    assert_eq!(text.lines().count(), 4, "{text}");
    for algo in ["scale", "fedavg", "hfl"] {
        assert!(
            text.lines().any(|l| l.ends_with(&format!(",{algo}"))),
            "missing {algo} row:\n{text}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_run_with_sampling_has_thread_parity() {
    // --sample end to end through the real binary: the printed
    // fingerprint hash must match between --threads 1 and --threads 4
    let dir = temp_dir("sample");
    let toml = dir.join("scenario.toml");
    let out = run(&["scenario", "gen", "--out", toml.to_str().unwrap()]);
    assert!(out.status.success(), "gen failed: {out:?}");

    let fingerprint = |threads: &str| -> String {
        let out = run(&[
            "scenario",
            "run",
            "--file",
            toml.to_str().unwrap(),
            "--sample",
            "0.5",
            "--threads",
            threads,
        ]);
        assert!(out.status.success(), "--sample 0.5 --threads {threads}: {out:?}");
        let stdout = String::from_utf8_lossy(&out.stdout);
        stdout
            .lines()
            .find(|l| l.starts_with("fingerprint"))
            .unwrap_or_else(|| panic!("no fingerprint line:\n{stdout}"))
            .to_string()
    };
    assert_eq!(
        fingerprint("1"),
        fingerprint("4"),
        "--sample diverged between threads 1 and 4"
    );
    // out-of-range fractions fail fast with a helpful message
    let out = run(&[
        "scenario",
        "run",
        "--file",
        toml.to_str().unwrap(),
        "--sample",
        "1.5",
    ]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("sample_frac"), "unhelpful error: {stderr}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fleet_bench_sampling_writes_csv_with_sample_and_rss_columns() {
    let dir = temp_dir("fleet_sample");
    let csv = dir.join("fleet.csv");
    let out = run(&[
        "fleet",
        "bench",
        "--nodes",
        "60",
        "--clusters",
        "6",
        "--rounds",
        "3",
        "--preset",
        "fleet-1k",
        "--threads",
        "2",
        "--sample",
        "0.2",
        "--csv",
        csv.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "sampled fleet bench failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("identical"), "{stdout}");
    assert!(stdout.contains("sampling"), "no sampling line:\n{stdout}");
    let text = std::fs::read_to_string(&csv).expect("csv written");
    let header = text.lines().next().unwrap();
    assert!(header.contains("sample_frac"), "{header}");
    assert!(header.contains("peak_rss_mb"), "{header}");
    let row = text.lines().nth(1).unwrap();
    let cols: Vec<&str> = row.split(',').collect();
    assert_eq!(cols.len(), header.split(',').count(), "{row}");
    // sample_frac lands in its column (third from the end)
    assert_eq!(cols[cols.len() - 3], "0.2", "{row}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_run_without_file_exits_nonzero() {
    let out = run(&["scenario", "run"]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--file"), "unhelpful error: {stderr}");
}

#[test]
fn fleet_bench_small_is_deterministic_and_writes_csv() {
    let dir = temp_dir("fleet");
    let csv = dir.join("fleet.csv");
    // a deliberately tiny fleet so the integration test stays fast; the
    // command hard-fails internally if fingerprints diverge
    let out = run(&[
        "fleet",
        "bench",
        "--nodes",
        "60",
        "--clusters",
        "6",
        "--rounds",
        "3",
        "--preset",
        "fleet-1k",
        "--threads",
        "2",
        "--csv",
        csv.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "fleet bench failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("identical"), "{stdout}");
    let text = std::fs::read_to_string(&csv).expect("csv written");
    assert!(text.starts_with("nodes,clusters,rounds,threads"), "{text}");
    assert_eq!(text.lines().count(), 2, "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fleet_bench_wire_flags_report_bytes_reduction() {
    let dir = temp_dir("wire");
    let csv = dir.join("wire.csv");
    // --codec i8 --delta triggers the f32 reference run and the
    // bytes-on-wire reduction report, end-to-end through the CLI
    let out = run(&[
        "fleet",
        "bench",
        "--nodes",
        "60",
        "--clusters",
        "6",
        "--rounds",
        "3",
        "--preset",
        "fleet-1k",
        "--threads",
        "2",
        "--codec",
        "i8",
        "--delta",
        "--csv",
        csv.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "wire fleet bench failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("identical"), "{stdout}");
    assert!(stdout.contains("reduction"), "no wire reduction line:\n{stdout}");
    assert!(stdout.contains("i8+delta"), "{stdout}");
    let text = std::fs::read_to_string(&csv).expect("csv written");
    assert!(text.contains("i8+delta"), "{text}");
    // unknown codec names fail fast
    let out = run(&["fleet", "bench", "--codec", "mp3"]);
    assert!(!out.status.success());
    std::fs::remove_dir_all(&dir).ok();
}
