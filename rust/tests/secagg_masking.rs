//! Secure-aggregation integration suite: the masked collect path
//! (DESIGN.md §11) through the full unified engine.
//!
//! The contract under test:
//! * with `--secagg` on, collect-phase uploads ride masked fixed-point
//!   frames (bigger on the wire than the plaintext path — the privacy
//!   tax) and reveal traffic appears only when a cohort member drops
//!   mid-round;
//! * fingerprints stay byte-identical across reruns and `--threads`
//!   1 vs N, including rounds with mid-round departures and dropout
//!   recovery;
//! * suspend/resume through masked dropout rounds reproduces the
//!   uninterrupted fingerprint (the `left_this_round` markers are
//!   recomputed, never serialized);
//! * a survivor count below `--secagg-threshold` aborts that cluster's
//!   round gracefully — counted in `secagg_aborts`, run completes;
//! * structurally tampered masked frames are rejected at parse time,
//!   and a payload flip never decodes back to the original words.

mod common;

use std::path::{Path, PathBuf};

use common::{native, small_cfg};
use scale_fl::config::SimConfig;
use scale_fl::netsim::MsgKind;
use scale_fl::obs::{self, Counter, ObsConfig};
use scale_fl::scenario::Scenario;
use scale_fl::secagg::{self, Session};
use scale_fl::sim::report::RunReport;
use scale_fl::sim::{AlgoKind, RunCtl, RunOutcome, RunState, Simulation};
use scale_fl::util::prop::{check, Config};
use scale_fl::wire::Frame;

/// Per-process scratch dir so parallel test binaries never collide.
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("scale_secagg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The common small federation with masking on, trimmed to 6 rounds so
/// the resume sweep stays fast.
fn secagg_cfg(threads: usize) -> SimConfig {
    let mut cfg = small_cfg();
    cfg.rounds = 6;
    cfg.threads = threads;
    cfg.secure_aggregation = true;
    cfg.normalized()
}

/// Churn timeline with a leave event early enough that masked dropout
/// recovery runs mid-suite (same shape as the resume suite's fixture).
const CHURN: &str = "[regulation]\nmin_live_frac = 0.7\ncooldown = 1\n\
     [[event]]\nround = 1\nkind = \"leave\"\nfrac = 0.3\nduration = 2\n\
     [[event]]\nround = 3\nkind = \"drift\"\nfrac = 0.2\nflip_frac = 0.3\n";

fn run(cfg: &SimConfig, scenario: &Scenario) -> RunReport {
    let compute = native();
    let mut sim = Simulation::new_parallel(cfg.clone(), &compute).unwrap();
    sim.run_algo(AlgoKind::Scale, scenario).unwrap()
}

#[test]
fn masked_frames_widen_the_collect_leg_and_reveals_need_dropout() {
    // identical federation, masking on vs off, nobody ever drops: the
    // collect leg carries the same number of transfers but each one is
    // a fixed-point masked frame (8 bytes/param, no envelope) instead
    // of the plaintext payload — and no reveal traffic exists at all
    let mut on = secagg_cfg(1);
    on.rounds = 4;
    let mut off = on.clone();
    off.secure_aggregation = false;

    let rep_on = run(&on, &Scenario::none());
    let rep_off = run(&off, &Scenario::none());

    let collect_on = rep_on.ledger.get(&MsgKind::DriverCollect).copied().unwrap_or_default();
    let collect_off = rep_off.ledger.get(&MsgKind::DriverCollect).copied().unwrap_or_default();
    assert_eq!(
        collect_on.count, collect_off.count,
        "masking must not change who uploads, only what the bytes look like"
    );
    assert!(collect_on.count > 0);
    assert!(
        collect_on.bytes > collect_off.bytes,
        "masked collect must cost more on the wire (privacy tax): {} vs {}",
        collect_on.bytes,
        collect_off.bytes
    );
    // no departures → no recovery traffic, in either run
    assert!(rep_on.ledger.get(&MsgKind::SecaggReveal).is_none(), "{:?}", rep_on.ledger);
    assert!(rep_off.ledger.get(&MsgKind::SecaggReveal).is_none());
}

#[test]
fn secagg_churn_fingerprint_is_rerun_stable_and_thread_invariant() {
    let scenario = Scenario::from_toml(CHURN).unwrap();
    let seq = run(&secagg_cfg(1), &scenario);
    let seq_again = run(&secagg_cfg(1), &scenario);
    assert_eq!(
        seq.fingerprint(),
        seq_again.fingerprint(),
        "masked run must be bit-reproducible"
    );
    let par = run(&secagg_cfg(4), &scenario);
    assert_eq!(
        seq.fingerprint(),
        par.fingerprint(),
        "masked run diverged between threads 1 and 4"
    );
    // the leave event left cohort masks outstanding: dropout recovery
    // actually ran, and its reveal traffic is on the ledger
    let reveals = seq.ledger.get(&MsgKind::SecaggReveal).copied().unwrap_or_default();
    assert!(reveals.count > 0, "churn produced no reveal traffic: {:?}", seq.ledger);
    assert_eq!(
        reveals.bytes,
        reveals.count * secagg::REVEAL_BYTES,
        "every reveal is a fixed-size control message"
    );
}

/// Suspend after `stop_after` rounds, drop everything, reload the
/// signed snapshot and finish — the resume suite's kill fixture, here
/// driven through masked dropout rounds.
fn killed_and_resumed(
    cfg: &SimConfig,
    scenario: &Scenario,
    stop_after: usize,
    state: &Path,
) -> String {
    let compute = native();
    let mut sim = Simulation::new_parallel(cfg.clone(), &compute).unwrap();
    let ctl = RunCtl {
        stop_after: Some(stop_after),
        state_out: Some(state.to_path_buf()),
        ..RunCtl::default()
    };
    match sim.run_algo_ctl(AlgoKind::Scale, scenario, ctl).unwrap() {
        RunOutcome::Suspended { rounds_done, .. } => assert_eq!(rounds_done, stop_after),
        RunOutcome::Complete(_) => panic!("run with stop_after {stop_after} never suspended"),
    }
    drop(sim);

    let rs = RunState::load(state).unwrap();
    let mut sim = Simulation::new_parallel(rs.cfg.clone(), &compute).unwrap();
    let ctl = RunCtl { resume: Some(rs), ..RunCtl::default() };
    match sim.run_algo_ctl(AlgoKind::Scale, scenario, ctl).unwrap() {
        RunOutcome::Complete(rep) => rep.fingerprint(),
        RunOutcome::Suspended { .. } => panic!("resumed run suspended again"),
    }
}

#[test]
fn resume_through_masked_dropout_rounds_is_byte_identical() {
    // suspension points straddle the leave event (round 1) and the
    // drift event (round 3): the restored run re-derives the departure
    // markers from the replayed scenario — they are never serialized
    let scenario = Scenario::from_toml(CHURN).unwrap();
    for threads in [1usize, 4] {
        let cfg = secagg_cfg(threads);
        let full = run(&cfg, &scenario).fingerprint();
        for stop_after in [2usize, 4] {
            let state = tmp(&format!("masked_{threads}_{stop_after}.state"));
            let resumed = killed_and_resumed(&cfg, &scenario, stop_after, &state);
            assert_eq!(
                full, resumed,
                "masked resume diverged at --threads {threads}, stop_after {stop_after}"
            );
        }
    }
}

#[test]
fn below_threshold_dropout_aborts_gracefully() {
    // secagg_threshold = 1.0: ANY mid-round departure leaves fewer
    // survivors than the floor, so affected clusters must take the
    // abort path (no consensus, no upload) without failing the run —
    // and the telemetry registry counts every abort and masked frame
    let scenario = Scenario::from_toml(CHURN).unwrap();
    let mut cfg = secagg_cfg(1);
    cfg.secagg_threshold = 1.0;

    obs::install(&ObsConfig { enabled: true, ..Default::default() }).unwrap();
    let strict = run(&cfg, &scenario);
    let snap = obs::snapshot();
    obs::finish().unwrap();
    assert!(
        snap.counter(Counter::SecaggAborts) > 0,
        "a 100% survival floor under churn must abort at least one cluster round"
    );
    assert!(snap.counter(Counter::MaskedFrames) > 0, "clean rounds still mask");

    // the strict run is reproducible too (the abort path is part of
    // the deterministic round, not an error path)
    assert_eq!(strict.fingerprint(), run(&cfg, &scenario).fingerprint());

    // a permissive floor recovers instead of aborting, so the strict
    // run can never upload more than it does
    let mut lax = cfg.clone();
    lax.secagg_threshold = 0.0;
    let relaxed = run(&lax, &scenario);
    assert!(
        strict.total_updates() <= relaxed.total_updates(),
        "aborted rounds produced uploads: strict {} vs lax {}",
        strict.total_updates(),
        relaxed.total_updates()
    );
}

#[test]
fn property_masks_cancel_bit_for_bit_over_complete_cohorts() {
    // the tentpole invariant at the library boundary: for ANY cohort,
    // round, cluster and weights, the wrapping sum of the masked
    // fixed-point vectors equals the sum of the clear encodings exactly
    check(&Config { cases: 50, ..Default::default() }, "masked sum == clear sum", |g| {
        let n = g.usize_in(1, 9);
        let dim = g.usize_in(1, 40);
        let mut root = [0u8; 32];
        for b in root.iter_mut() {
            *b = g.usize_in(0, 255) as u8;
        }
        let ids: Vec<u64> = (0..n as u64).map(|i| i * 3 + 7).collect();
        let session =
            Session::new(&root, g.usize_in(0, 30) as u32, g.usize_in(0, 9) as u32, ids.clone());
        let encoded: Vec<Vec<i64>> = (0..n)
            .map(|_| {
                let xs: Vec<f32> = (0..dim).map(|_| g.rng.f32() * 8.0 - 4.0).collect();
                secagg::encode_fixed(&xs)
            })
            .collect();
        let masked: Vec<Vec<i64>> =
            ids.iter().zip(&encoded).map(|(&id, e)| session.mask(id, e)).collect();
        if secagg::sum_masked(&masked) != secagg::sum_masked(&encoded) {
            return Err(format!("cancellation failed for cohort of {n}, dim {dim}"));
        }
        Ok(())
    });
}

#[test]
fn tampered_masked_frames_never_pass_as_pristine() {
    // a realistic masked vector from a real session, serialized the way
    // the driver receives it
    let root = [9u8; 32];
    let ids: Vec<u64> = (0..5).collect();
    let session = Session::new(&root, 3, 1, ids);
    let params: Vec<f32> = (0..33).map(|i| i as f32 * 0.03 - 0.5).collect();
    let words = session.mask(2, &secagg::encode_fixed(&params));
    let frame = Frame::masked_frame(3, &words);
    let bytes = frame.to_bytes();
    assert_eq!(bytes.len() as u64, Frame::masked_frame_bytes(33));

    // every truncation is rejected at parse
    for cut in [0, 4, bytes.len() / 2, bytes.len() - 1] {
        assert!(Frame::from_bytes(&bytes[..cut]).is_err(), "truncation to {cut} accepted");
    }
    // validated header regions: magic, version, codec, flags,
    // baseline_round, dim — a flip in any of them is rejected
    for pos in [0usize, 4, 5, 6, 12, 16] {
        let mut bad = bytes.clone();
        bad[pos] ^= 0x10;
        assert!(Frame::from_bytes(&bad).is_err(), "header flip at byte {pos} accepted");
    }
    // payload flips parse (the frame is structurally valid — integrity
    // of the masked words rides the transport layer, DESIGN §11) but
    // can never reproduce the original words
    for pos in [20usize, 21, bytes.len() - 8, bytes.len() - 1] {
        let mut bad = bytes.clone();
        bad[pos] ^= 0x10;
        let parsed = Frame::from_bytes(&bad).unwrap();
        assert_ne!(
            parsed.masked_values().unwrap(),
            words,
            "payload flip at byte {pos} decoded as pristine"
        );
    }
}

#[test]
fn library_recovery_matches_survivor_only_mean_through_the_wire_format() {
    // end-to-end through the exact driver steps of secagg_collect:
    // encode → mask → frame → bytes → parse → accumulate → reveal →
    // unmask → decode, with one member dropped — against the plaintext
    // survivor mean
    let root = [7u8; 32];
    let ids: Vec<u64> = vec![10, 11, 12, 13];
    let session = Session::new(&root, 4, 0, ids.clone());
    let params: Vec<Vec<f32>> = (0..4)
        .map(|i| (0..21).map(|j| ((i + 2) * (j + 1)) as f32 * 0.01 - 0.3).collect())
        .collect();
    let dropped = [13u64];
    let survivors: Vec<u64> = ids.iter().copied().filter(|i| !dropped.contains(i)).collect();

    let mut masked = Vec::new();
    for (i, &id) in ids.iter().enumerate() {
        if dropped.contains(&id) {
            continue; // its frame never arrives
        }
        let words = session.mask(id, &secagg::encode_fixed(&params[i]));
        let received = Frame::from_bytes(&Frame::masked_frame(4, &words).to_bytes()).unwrap();
        masked.push(received.masked_values().unwrap());
    }
    let mut sum = secagg::sum_masked(&masked);
    let reveals: Vec<secagg::Reveal> = survivors
        .iter()
        .flat_map(|&s| dropped.iter().map(move |&d| (s, d)))
        .map(|(s, d)| session.reveal(s, d))
        .collect();
    session.unmask_sum(&mut sum, &survivors, &dropped, &reveals).unwrap();
    let mean = secagg::decode_mean(&sum, survivors.len());

    for d in 0..21 {
        let plain: f64 = params[..3].iter().map(|p| p[d] as f64).sum::<f64>() / 3.0;
        assert!(
            (mean[d] as f64 - plain).abs() < 1e-5,
            "dim {d}: recovered {} vs plaintext {plain}",
            mean[d]
        );
    }
}
