//! Integration: the full SCALE system over the PJRT backend — MLP model
//! family, extension combinations (quantized exchange, secure
//! aggregation), config round trips through the CLI surface, and trace
//! exports. Skips PJRT-dependent cases when artifacts are absent or the
//! `pjrt` feature is off.

#[cfg(feature = "pjrt")]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

mod common;

use scale_fl::config::{Partition, SimConfig};
#[cfg(feature = "pjrt")]
use scale_fl::netsim::MsgKind;
#[cfg(feature = "pjrt")]
use scale_fl::runtime::compute::PjrtModel;
#[cfg(feature = "pjrt")]
use scale_fl::runtime::manifest::ModelKind;
#[cfg(feature = "pjrt")]
use scale_fl::runtime::Runtime;
use scale_fl::sim::Simulation;

#[cfg(feature = "pjrt")]
fn runtime() -> Option<Rc<Runtime>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json")
        .exists()
        .then(|| Rc::new(Runtime::open(&dir).expect("runtime open")))
}

fn small_cfg() -> SimConfig {
    SimConfig {
        n_nodes: 16,
        n_clusters: 4,
        rounds: 6,
        local_epochs: 2,
        eval_every: 3,
        dataset_samples: 320,
        dataset_malignant: 120,
        seed: 9,
        ..Default::default()
    }
    .normalized()
}

#[cfg(feature = "pjrt")]
#[test]
fn mlp_model_family_runs_scale_through_pjrt() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let compute = PjrtModel::new(rt, ModelKind::Mlp);
    let mut cfg = small_cfg();
    cfg.model = ModelKind::Mlp;
    cfg.lr = 0.15;
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let report = sim.run_scale().unwrap();
    assert_eq!(report.clusters.len(), 4);
    assert!(report.final_metrics.accuracy > 0.7, "{:?}", report.final_metrics);
    // MLP params (545) flow through aggregate_mlp
    let payload = report.ledger[&MsgKind::PeerExchange].bytes
        / report.ledger[&MsgKind::PeerExchange].count;
    assert_eq!(payload, 545 * 4 + 64);
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_svm_agree_on_protocol_outputs() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = small_cfg();
    let pjrt = PjrtModel::new(rt, ModelKind::Svm);
    let native = common::native();

    let mut sim_p = Simulation::new(cfg.clone(), &pjrt).unwrap();
    let rep_p = sim_p.run_scale().unwrap();
    let mut sim_n = Simulation::new(cfg, &native).unwrap();
    let rep_n = sim_n.run_scale().unwrap();

    // identical protocol decisions (same seeds); numerics within f32 drift
    assert_eq!(rep_p.total_updates(), rep_n.total_updates());
    assert_eq!(
        rep_p.ledger[&MsgKind::PeerExchange].count,
        rep_n.ledger[&MsgKind::PeerExchange].count
    );
    assert!(
        (rep_p.final_metrics.accuracy - rep_n.final_metrics.accuracy).abs() < 0.03,
        "pjrt {} vs native {}",
        rep_p.final_metrics.accuracy,
        rep_n.final_metrics.accuracy
    );
}

#[test]
fn extension_matrix_native() {
    let native = common::native();
    for (quant, secagg) in [(false, false), (true, false), (false, true), (true, true)] {
        let mut cfg = small_cfg();
        cfg.quantize_exchange = quant;
        cfg.secure_aggregation = secagg;
        let mut sim = Simulation::new(cfg, &native).unwrap();
        let rep = sim.run_scale().unwrap();
        assert!(
            rep.final_metrics.accuracy > 0.75,
            "quant={quant} secagg={secagg}: {:?}",
            rep.final_metrics
        );
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn skewed_mlp_with_failures_and_secagg() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let compute = PjrtModel::new(rt, ModelKind::Mlp);
    let mut cfg = small_cfg();
    cfg.model = ModelKind::Mlp;
    cfg.partition = Partition::LabelSkew(0.5);
    cfg.node_failure_prob = 0.15;
    cfg.node_recovery_prob = 0.6;
    cfg.secure_aggregation = true;
    cfg.lr = 0.15;
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let report = sim.run_scale().unwrap();
    // survives the combination and still learns something nontrivial
    assert!(report.final_metrics.roc_auc > 0.6, "{:?}", report.final_metrics);
    let elections: u64 = report.clusters.iter().map(|c| c.elections).sum();
    assert!(elections >= 4);
}

#[test]
fn trace_export_from_real_run() {
    let native = common::native();
    let mut sim = Simulation::new(small_cfg(), &native).unwrap();
    let report = sim.run_scale().unwrap();
    let dir = std::env::temp_dir().join(format!("scale_it_{}", std::process::id()));
    scale_fl::trace::write_run(&dir, &report).unwrap();
    let rounds = std::fs::read_to_string(dir.join("scale_rounds.csv")).unwrap();
    assert_eq!(rounds.lines().count(), 1 + report.rounds.len());
    let clusters = std::fs::read_to_string(dir.join("scale_clusters.csv")).unwrap();
    assert_eq!(clusters.lines().count(), 1 + report.clusters.len());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_json_drives_simulation() {
    // full path: config -> JSON -> file -> load -> run
    let mut cfg = small_cfg();
    cfg.quantize_exchange = true;
    cfg.partition = Partition::LabelSkew(0.7);
    let path = std::env::temp_dir().join(format!("scale_cfg_it_{}.json", std::process::id()));
    cfg.save(&path).unwrap();
    let loaded = SimConfig::load(&path).unwrap();
    assert_eq!(loaded.quantize_exchange, true);
    assert_eq!(loaded.partition, Partition::LabelSkew(0.7));
    let native = common::native();
    let mut sim = Simulation::new(loaded, &native).unwrap();
    assert!(sim.run_scale().is_ok());
    std::fs::remove_file(&path).ok();
}
