//! Algorithm-level integration suite: SCALE, FedAvg and HFL end-to-end
//! through the unified `sim::engine` path — protocol behaviour,
//! extension combinations (quantized exchange, secure aggregation, wire
//! presets), the three-way comparisons behind the paper's tables, and
//! the `--threads 1` vs N fingerprint parity contract.
//!
//! (Moved out of `sim/mod.rs` when the monolith was dismantled; the
//! shared setup lives in `tests/common`.)

mod common;

use common::{native, small_cfg};
use scale_fl::config::{CheckpointMode, Partition};
use scale_fl::netsim::MsgKind;
use scale_fl::runtime::compute::ModelCompute;
use scale_fl::scenario::Scenario;
use scale_fl::sim::report::RunReport;
use scale_fl::sim::{AlgoKind, Simulation};

#[test]
fn scale_run_end_to_end_native() {
    let compute = native();
    let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
    let report = sim.run_scale().unwrap();
    assert_eq!(report.rounds.len(), 8);
    assert_eq!(report.clusters.len(), 4);
    // every cluster uploads at least once (first observation is free)
    assert!(report.clusters.iter().all(|c| c.updates >= 1));
    // checkpoint gating never exceeds one upload per driver-round
    assert!(report.total_updates() <= 8 * 4);
    // the model actually learns
    // label_noise=0.05 bounds achievable accuracy/AUC on noisy labels
    assert!(report.final_metrics.accuracy > 0.8, "{:?}", report.final_metrics);
    assert!(report.final_metrics.roc_auc > 0.85);
    // ledger sanity
    assert_eq!(
        report.ledger[&MsgKind::GlobalUpdate].count,
        report.total_updates()
    );
    assert!(report.ledger[&MsgKind::PeerExchange].count > 0);
    assert!(report.ledger[&MsgKind::Summary].count == 20);
    assert!(report.comm_energy_j > 0.0);
    assert!(report.compute_energy_j > 0.0);
}

#[test]
fn fedavg_run_end_to_end_native() {
    let compute = native();
    let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
    let grouping = sim.scale_grouping().unwrap();
    let report = sim.run_fedavg(Some(grouping)).unwrap();
    // every live node uploads every round (no failures configured)
    assert_eq!(report.total_updates(), 20 * 8);
    assert!(report.final_metrics.accuracy > 0.85);
    assert_eq!(report.clusters.len(), 4);
    assert_eq!(report.ledger[&MsgKind::GlobalUpdate].count, 20 * 8);
}

#[test]
fn scale_beats_fedavg_on_updates_at_similar_accuracy() {
    let compute = native();
    let cfg = small_cfg();
    let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
    let scale = sim.run_scale().unwrap();
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let fedavg = sim.run_fedavg(None).unwrap();
    assert!(
        (scale.total_updates() as f64) < fedavg.total_updates() as f64 * 0.6,
        "scale {} vs fedavg {}",
        scale.total_updates(),
        fedavg.total_updates()
    );
    assert!(
        (scale.final_metrics.accuracy - fedavg.final_metrics.accuracy).abs() < 0.08,
        "scale {} vs fedavg {}",
        scale.final_metrics.accuracy,
        fedavg.final_metrics.accuracy
    );
}

#[test]
fn deterministic_given_seed() {
    let compute = native();
    let run = || {
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        let r = sim.run_scale().unwrap();
        (
            r.total_updates(),
            r.final_metrics.accuracy,
            r.ledger[&MsgKind::PeerExchange].count,
        )
    };
    assert_eq!(run(), run());
}

#[test]
fn failure_injection_triggers_elections_and_survives() {
    let compute = native();
    let mut cfg = small_cfg();
    cfg.node_failure_prob = 0.25;
    cfg.node_recovery_prob = 0.5;
    cfg.rounds = 10;
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let report = sim.run_scale().unwrap();
    let elections: u64 = report.clusters.iter().map(|c| c.elections).sum();
    // initial elections (4) plus failover re-elections
    assert!(elections > 4, "elections {elections}");
    assert!(report.ledger[&MsgKind::Election].count > 0);
    // system still converges to a usable model
    assert!(report.final_metrics.accuracy > 0.7, "{:?}", report.final_metrics);
}

#[test]
fn label_skew_partition_still_learns() {
    let compute = native();
    let mut cfg = small_cfg();
    cfg.partition = Partition::LabelSkew(0.4);
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let report = sim.run_scale().unwrap();
    assert!(report.final_metrics.accuracy > 0.75, "{:?}", report.final_metrics);
}

#[test]
fn tighter_checkpoint_gate_reduces_updates() {
    let compute = native();
    let updates_at = |delta: f64| {
        let mut cfg = small_cfg();
        cfg.rounds = 16;
        cfg.checkpoint_min_delta = delta;
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        sim.run_scale().unwrap().total_updates()
    };
    let loose = updates_at(0.0);
    let mid = updates_at(0.08);
    let tight = updates_at(0.8);
    assert!(mid <= loose, "mid {mid} loose {loose}");
    assert!(tight <= mid, "tight {tight} mid {mid}");
    // a param-delta gate of 80% relative change ≈ first + forced final
    assert!(tight <= 4 * 3, "tight {tight}");
    // convergence tapering: the delta gate must skip some late rounds
    assert!(mid < 16 * 4, "mid {mid} never skipped");
}

#[test]
fn accuracy_gate_mode_is_most_aggressive() {
    let compute = native();
    let run = |mode: CheckpointMode| {
        let mut cfg = small_cfg();
        cfg.checkpoint_mode = mode;
        cfg.checkpoint_min_delta = 0.002;
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        sim.run_scale().unwrap().total_updates()
    };
    let acc = run(CheckpointMode::Accuracy);
    let delta = run(CheckpointMode::ParamDelta);
    assert!(acc <= delta, "accuracy {acc} vs delta {delta}");
}

#[test]
fn hfl_baseline_runs_and_counts_edge_tier() {
    let compute = native();
    let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
    let report = sim.run_hfl(3).unwrap();
    // one cluster report per (non-empty) metro edge
    assert!(!report.clusters.is_empty());
    // cloud updates: edges * ceil-ish(rounds / period) incl. final
    let n_edges = report.clusters.len() as u64;
    let expected_syncs = (8usize / 3 + 1) as u64; // rounds 3,6,8(final)
    assert_eq!(report.total_updates(), n_edges * expected_syncs);
    // edge tier carries the per-round traffic
    assert!(report.ledger[&MsgKind::EdgeUpdate].count >= 8 * 10);
    assert!(report.ledger[&MsgKind::EdgeBroadcast].count >= 8 * 10);
    // infrastructure cost is nonzero (the cost SCALE avoids)
    assert!(report.edge_cost_usd > 0.0);
    assert!(report.final_metrics.accuracy > 0.8, "{:?}", report.final_metrics);
}

#[test]
fn hfl_between_fedavg_and_scale_on_cloud_updates() {
    let compute = native();
    let cfg = small_cfg();
    let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
    let scale = sim.run_scale().unwrap();
    let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
    let hfl = sim.run_hfl(2).unwrap();
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let fedavg = sim.run_fedavg(None).unwrap();
    assert!(hfl.total_updates() < fedavg.total_updates());
    // SCALE has no edge infrastructure bill
    assert_eq!(scale.edge_cost_usd, 0.0);
    assert!(hfl.edge_cost_usd > 0.0);
}

#[test]
fn quantized_exchange_shrinks_bytes_and_holds_accuracy() {
    let compute = native();
    let run = |q: bool| {
        let mut cfg = small_cfg();
        cfg.quantize_exchange = q;
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        sim.run_scale().unwrap()
    };
    let plain = run(false);
    let quant = run(true);
    let bytes = |r: &RunReport| r.ledger[&MsgKind::PeerExchange].bytes;
    // i8 frames at svm_dim=33: 20-byte header + 12+33 payload = 65 B
    // vs the 196 B f32 passthrough envelope (~3x)
    assert!(
        bytes(&quant) * 3 < bytes(&plain) * 2,
        "quantized {} vs plain {}",
        bytes(&quant),
        bytes(&plain)
    );
    assert!(
        (quant.final_metrics.accuracy - plain.final_metrics.accuracy).abs() < 0.05,
        "quant acc {} vs plain {}",
        quant.final_metrics.accuracy,
        plain.final_metrics.accuracy
    );
}

#[test]
fn wire_passthrough_matches_legacy_payload_bytes() {
    // the lossless-fingerprint contract at the byte level: with the
    // default wire config every parameter transfer must cost exactly
    // the seed's param_payload_bytes model
    let compute = native();
    let dim = compute.param_dim();
    let legacy = scale_fl::netsim::param_payload_bytes(dim);
    let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
    let r = sim.run_scale().unwrap();
    for kind in [
        MsgKind::PeerExchange,
        MsgKind::DriverCollect,
        MsgKind::DriverBroadcast,
        MsgKind::GlobalUpdate,
    ] {
        let t = r.ledger[&kind];
        assert_eq!(t.bytes, t.count * legacy, "{kind:?}");
    }
    let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
    let f = sim.run_fedavg(None).unwrap();
    for kind in [MsgKind::GlobalUpdate, MsgKind::GlobalBroadcast] {
        let t = f.ledger[&kind];
        assert_eq!(t.bytes, t.count * legacy, "fedavg {kind:?}");
    }
}

#[test]
fn lean_wire_cuts_param_bytes_and_stays_thread_invariant() {
    let compute = native();
    let run = |wire: scale_fl::wire::WireConfig, threads: usize| {
        let mut cfg = small_cfg();
        cfg.wire = wire;
        cfg.threads = threads;
        let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
        sim.run_scale().unwrap()
    };
    let lean = scale_fl::wire::WireConfig::preset("lean").unwrap();
    let plain = run(scale_fl::wire::WireConfig::default(), 1);
    let seq = run(lean, 1);
    let par = run(lean, 4);
    // the lossy-codec path honours the parallel determinism contract
    assert_eq!(seq.fingerprint(), par.fingerprint());
    // i8 + delta + top-k sparsification cuts the param path hard
    assert!(
        plain.param_path_bytes() >= 3 * seq.param_path_bytes(),
        "plain {} vs lean {}",
        plain.param_path_bytes(),
        seq.param_path_bytes()
    );
    // and the federation still trains a usable model
    assert!(
        seq.final_metrics.accuracy > 0.55,
        "lean accuracy {:?}",
        seq.final_metrics
    );
}

#[test]
fn lean_wire_uniform_frames_match_ledger_accounting() {
    // with the baseline ring primed at formation, every PeerExchange
    // frame in a scenario-free run has the same encoded size — the
    // ledger must agree with WireConfig::frame_bytes exactly
    let compute = native();
    let mut cfg = small_cfg();
    cfg.wire = scale_fl::wire::WireConfig::preset("lean").unwrap();
    let per_frame = cfg.wire.frame_bytes(compute.param_dim(), true);
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let r = sim.run_scale().unwrap();
    for kind in [MsgKind::PeerExchange, MsgKind::DriverBroadcast] {
        let t = r.ledger[&kind];
        assert_eq!(t.bytes, t.count * per_frame, "{kind:?}");
    }
}

#[test]
fn secure_aggregation_preserves_consensus() {
    let compute = native();
    let run = |sa: bool| {
        let mut cfg = small_cfg();
        cfg.secure_aggregation = sa;
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        sim.run_scale().unwrap()
    };
    let plain = run(false);
    let secure = run(true);
    // fixed-point masking must be metrically invisible
    assert!(
        (secure.final_metrics.accuracy - plain.final_metrics.accuracy).abs() < 0.02,
        "secure {} vs plain {}",
        secure.final_metrics.accuracy,
        plain.final_metrics.accuracy
    );
    // ...but the collect payloads are 2x (i64 vs f32)
    let bytes = |r: &RunReport| r.ledger[&MsgKind::DriverCollect].bytes;
    assert!(bytes(&secure) > bytes(&plain));
    assert_eq!(secure.total_updates(), plain.total_updates());
}

#[test]
fn round_latency_positive_and_loss_decreases() {
    let compute = native();
    let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
    let report = sim.run_scale().unwrap();
    assert!(report.rounds.iter().all(|r| r.latency_ms > 0.0));
    let first = report.rounds.first().unwrap().mean_loss;
    let last = report.rounds.last().unwrap().mean_loss;
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn parallel_scale_rounds_are_fingerprint_identical() {
    let compute = native();
    let fp = |threads: usize| {
        let mut cfg = small_cfg();
        cfg.threads = threads;
        let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
        sim.run_scale().unwrap().fingerprint()
    };
    let base = fp(1);
    assert_eq!(fp(2), base, "threads=2 diverged");
    assert_eq!(fp(5), base, "threads=5 diverged");
    // the sequential constructor takes the same per-cluster path
    let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
    assert_eq!(sim.run_scale().unwrap().fingerprint(), base);
}

#[test]
fn parallel_baselines_are_fingerprint_identical() {
    let compute = native();
    let run = |threads: usize| {
        let mut cfg = small_cfg();
        cfg.threads = threads;
        let mut sim = Simulation::new_parallel(cfg.clone(), &compute).unwrap();
        let fedavg = sim.run_fedavg(None).unwrap().fingerprint();
        let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
        let hfl = sim.run_hfl(3).unwrap().fingerprint();
        (fedavg, hfl)
    };
    assert_eq!(run(1), run(4));
}

#[test]
fn parallel_scale_under_churn_and_failures_matches_sequential() {
    let scenario = Scenario::from_toml(
        "[regulation]\nmin_live_frac = 0.7\ncooldown = 1\n\
         [[event]]\nround = 1\nkind = \"leave\"\nfrac = 0.3\nduration = 2\n\
         [[event]]\nround = 3\nkind = \"bandwidth\"\nfactor = 0.5\nduration = 2\n",
    )
    .unwrap();
    let compute = native();
    let fp = |threads: usize| {
        let mut cfg = small_cfg();
        cfg.rounds = 10;
        cfg.node_failure_prob = 0.15;
        cfg.node_recovery_prob = 0.5;
        cfg.threads = threads;
        let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
        sim.run_scale_scenario(&scenario).unwrap().fingerprint()
    };
    assert_eq!(fp(1), fp(4));
}

#[test]
fn baselines_run_churn_scenarios_with_thread_parity() {
    // the tentpole's new capability: FedAvg and HFL execute a scenario
    // timeline end-to-end through the unified engine, with the same
    // --threads 1 vs 4 fingerprint contract SCALE has
    let scenario = Scenario::from_toml(
        "[regulation]\nmin_live_frac = 0.7\ncooldown = 1\n\
         [[event]]\nround = 1\nkind = \"leave\"\nfrac = 0.3\nduration = 2\n\
         [[event]]\nround = 3\nkind = \"bandwidth\"\nfactor = 0.5\nduration = 2\n\
         [[event]]\nround = 4\nkind = \"straggler\"\nfrac = 0.2\nfactor = 3.0\nduration = 2\n",
    )
    .unwrap();
    let compute = native();
    for algo in [AlgoKind::FedAvg, AlgoKind::Hfl { edge_period: 2 }] {
        let run = |threads: usize| {
            let mut cfg = small_cfg();
            cfg.rounds = 10;
            cfg.threads = threads;
            let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
            sim.run_algo(algo, &scenario).unwrap()
        };
        let seq = run(1);
        let par = run(4);
        assert_eq!(
            seq.fingerprint(),
            par.fingerprint(),
            "{} diverged between threads 1 and 4",
            algo.label()
        );
        assert_eq!(seq.mode, algo.label());
        // the churn actually happened: events recorded, node count dips
        assert!(seq.rounds.iter().any(|r| r.scenario_events > 0));
        assert!(seq.rounds.iter().any(|r| r.live_nodes < 20));
        // ...and the timeline is logged like SCALE's
        assert!(seq.scenario.iter().any(|n| n.what.contains("churn")));
        // nodes return after the leave window: the final round sees the
        // full fleet again (no random failures configured)
        assert_eq!(seq.rounds.last().unwrap().live_nodes, 20);
    }
}

#[test]
fn run_algo_axis_matches_the_dedicated_wrappers() {
    // the unified --algo entry point is the same execution path as the
    // legacy wrappers — bit-identical reports
    let compute = native();
    let pair = |algo: AlgoKind| {
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        let via_axis = sim.run_algo(algo, &Scenario::none()).unwrap().fingerprint();
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        let via_wrapper = match algo {
            AlgoKind::Scale => sim.run_scale(),
            AlgoKind::FedAvg => sim.run_fedavg(None),
            AlgoKind::Hfl { edge_period } => sim.run_hfl(edge_period),
        }
        .unwrap()
        .fingerprint();
        (via_axis, via_wrapper)
    };
    for algo in AlgoKind::all() {
        let (axis, wrapper) = pair(algo);
        assert_eq!(axis, wrapper, "{} wrapper drifted from run_algo", algo.label());
    }
}

#[test]
fn full_participation_sampling_is_byte_identical_to_default() {
    // sample_frac = 1.0 must take the pre-sampling path exactly: no RNG
    // draws, no message reordering — fingerprints match the default
    // config byte for byte, for every algorithm
    let compute = native();
    for algo in AlgoKind::all() {
        let fp = |frac: Option<f64>| {
            let mut cfg = small_cfg();
            if let Some(f) = frac {
                cfg.sample_frac = f;
            }
            let mut sim = Simulation::new(cfg, &compute).unwrap();
            sim.run_algo(algo, &Scenario::none()).unwrap().fingerprint()
        };
        assert_eq!(
            fp(Some(1.0)),
            fp(None),
            "{}: sample_frac=1.0 moved the fingerprint",
            algo.label()
        );
    }
}

#[test]
fn sampled_rounds_are_thread_invariant_and_rerun_stable() {
    // the sampling determinism contract: with sample_frac < 1 the drawn
    // subsets derive from (seed, round, unit), so fingerprints are
    // identical for --threads 1 vs N and stable across re-runs
    let compute = native();
    for algo in AlgoKind::all() {
        let run = |threads: usize| {
            let mut cfg = small_cfg();
            cfg.sample_frac = 0.4;
            cfg.rounds = 6;
            cfg.threads = threads;
            let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
            sim.run_algo(algo, &Scenario::none()).unwrap().fingerprint()
        };
        let seq = run(1);
        assert_eq!(seq, run(4), "{}: sampled run diverged across threads", algo.label());
        assert_eq!(seq, run(1), "{}: sampled set unstable across re-runs", algo.label());
    }
}

#[test]
fn sampling_under_churn_keeps_thread_parity() {
    let scenario = Scenario::from_toml(
        "[regulation]\nmin_live_frac = 0.7\ncooldown = 1\n\
         [[event]]\nround = 1\nkind = \"leave\"\nfrac = 0.3\nduration = 2\n",
    )
    .unwrap();
    let compute = native();
    let fp = |threads: usize| {
        let mut cfg = small_cfg();
        cfg.sample_frac = 0.5;
        cfg.rounds = 8;
        cfg.threads = threads;
        let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
        sim.run_scale_scenario(&scenario).unwrap().fingerprint()
    };
    assert_eq!(fp(1), fp(4));
}

#[test]
fn sampling_cuts_param_traffic_but_keeps_uploads_flowing() {
    let compute = native();
    let run = |frac: f64| {
        let mut cfg = small_cfg();
        cfg.sample_frac = frac;
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        sim.run_scale().unwrap()
    };
    let full = run(1.0);
    let sampled = run(0.3);
    // non-sampled nodes skip the whole parameter path...
    assert!(
        sampled.param_path_bytes() < full.param_path_bytes() / 2,
        "sampled {} vs full {}",
        sampled.param_path_bytes(),
        full.param_path_bytes()
    );
    // ...but keep heartbeating,
    assert_eq!(
        sampled.ledger[&MsgKind::Heartbeat].count,
        full.ledger[&MsgKind::Heartbeat].count
    );
    // and the drivers (always sampled) keep the global model moving
    assert!(sampled.total_updates() >= sampled.clusters.len() as u64);
    assert!(sampled.final_metrics.accuracy > 0.6, "{:?}", sampled.final_metrics);
}

#[test]
fn fedavg_sampling_counts_participants_not_fleet() {
    let compute = native();
    let mut cfg = small_cfg();
    cfg.sample_frac = 0.25;
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let r = sim.run_fedavg(None).unwrap();
    // ceil(0.25 * shard) participants per round, not all 20 nodes
    let per_round = r.rounds.iter().map(|x| x.updates).max().unwrap();
    assert!(per_round < 20, "per-round updates {per_round}");
    assert!(per_round >= 1);
    assert_eq!(r.ledger[&MsgKind::GlobalUpdate].count, r.total_updates());
}

#[test]
fn threads_without_sync_backend_error_helpfully() {
    let compute = native();
    let mut cfg = small_cfg();
    cfg.threads = 4;
    // plain constructor drops the Sync marker, so fan-out must refuse
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let err = sim.run_scale().unwrap_err().to_string();
    assert!(err.contains("thread-safe"), "{err}");
}
