//! Old-vs-new hot-path equivalence suite (DESIGN.md §12).
//!
//! The kernel overhaul (fused hinge-loss training in
//! `runtime::kernel`, decode-free frame accumulation in
//! `aggregation::{FrameAccumulator, MaskedAccumulator}`, LPT
//! scheduling in `sim::par`) claims *value identity*: every
//! optimization performs the same floating-point / integer operations
//! in the same order as the loops it replaced. This suite pins that
//! claim against verbatim copies of the pre-fusion reference loops —
//! every comparison is `to_bits` equality, never a tolerance — and
//! closes with a fingerprint thread-parity run over deliberately
//! lopsided cluster sizes (the LPT scheduler's worst case).
//!
//! CI runs the suite twice (`SCALE_TEST_THREADS` 1 and 4) so the
//! scheduler leg covers both the sequential path and a genuinely
//! parallel one.

mod common;

use scale_fl::aggregation::{FrameAccumulator, MaskedAccumulator};
use scale_fl::data::{pad_batch, Dataset, PaddedBatch};
use scale_fl::runtime::compute::ModelCompute;
use scale_fl::sim::Simulation;
use scale_fl::util::rng::Rng;
use scale_fl::wire::{Frame, WireConfig};

// ---------------------------------------------------------------------
// Reference implementations: the naive pre-fusion loops, verbatim.
// ---------------------------------------------------------------------

/// The naive hinge-loss step `NativeSvm::train_step` ran before the
/// kernel rewrite: scalar inner loops, fresh gradient and output
/// vectors every call.
fn ref_train_step(
    batch: &PaddedBatch,
    params: &[f32],
    lr: f32,
    reg: f32,
) -> (Vec<f32>, f32) {
    let f = params.len() - 1;
    let (w, bias) = params.split_at(f);
    let mut gw = vec![0.0f32; f];
    let mut gb = 0.0f32;
    let mut loss_sum = 0.0f32;
    let mut n = 0.0f32;
    for r in 0..batch.batch {
        let m = batch.mask[r];
        if m == 0.0 {
            continue;
        }
        let row = &batch.x[r * f..(r + 1) * f];
        let mut s = bias[0];
        for j in 0..f {
            s += w[j] * row[j];
        }
        let y = batch.y[r];
        let margin = 1.0 - y * s;
        if margin > 0.0 {
            loss_sum += m * margin;
            let coef = m * y;
            for j in 0..f {
                gw[j] -= coef * row[j];
            }
            gb -= coef;
        }
        n += m;
    }
    let n = n.max(1.0);
    let mut w_sq = 0.0f32;
    let mut out = Vec::with_capacity(f + 1);
    for j in 0..f {
        w_sq += w[j] * w[j];
        let grad = gw[j] / n + reg * w[j];
        out.push(w[j] - lr * grad);
    }
    out.push(bias[0] - lr * (gb / n));
    (out, loss_sum / n + 0.5 * reg * w_sq)
}

/// The naive scores loop: `bias + w·x_r` per valid row, scalar dot.
fn ref_scores(batch: &PaddedBatch, params: &[f32]) -> Vec<f32> {
    let f = params.len() - 1;
    let (w, bias) = params.split_at(f);
    (0..batch.n_valid)
        .map(|r| {
            let mut s = bias[0];
            let row = &batch.x[r * f..(r + 1) * f];
            for j in 0..f {
                s += w[j] * row[j];
            }
            s
        })
        .collect()
}

/// A randomized batch: `rows` valid rows of dense features in [−2, 2],
/// labels in {−1, +1}, padded to the backend's static (64, 32) shape.
fn random_batch(rng: &mut Rng, rows: usize) -> PaddedBatch {
    let mut x = Vec::with_capacity(rows * 30);
    let mut y = Vec::with_capacity(rows);
    for _ in 0..rows {
        for _ in 0..30 {
            x.push(rng.f32() * 4.0 - 2.0);
        }
        y.push(if rng.chance(0.5) { 1.0 } else { -1.0 });
    }
    let ds = Dataset::new(x, y, 30);
    pad_batch(&ds, 0, 64, 32)
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: coord {i} ({x} vs {y})");
    }
}

// ---------------------------------------------------------------------
// Training-kernel equivalence
// ---------------------------------------------------------------------

#[test]
fn fused_train_step_is_bit_identical_to_reference() {
    let m = common::native();
    let mut rng = Rng::new(0x2EF_57E9);
    // sweep batch fill (empty, partial, full), params, lr, reg
    for case in 0..32 {
        let rows = [0usize, 1, 7, 40, 64][case % 5];
        let batch = random_batch(&mut rng, rows);
        let params: Vec<f32> = (0..33).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let lr = rng.f32() * 0.5 + 0.001;
        let reg = rng.f32() * 0.3;
        let (want_p, want_l) = ref_train_step(&batch, &params, lr, reg);
        let (got_p, got_l) = m.train_step(&batch, &params, lr, reg).unwrap();
        assert_bits_eq(&got_p, &want_p, &format!("case {case} params"));
        assert_eq!(got_l.to_bits(), want_l.to_bits(), "case {case} loss");
    }
}

#[test]
fn fused_train_steps_matches_repeated_reference_steps() {
    let m = common::native();
    let mut rng = Rng::new(0x57E9_100F);
    for &k in &[1usize, 3, 7] {
        let batch = random_batch(&mut rng, 48);
        let params: Vec<f32> = (0..33).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let (lr, reg) = (0.1f32, 0.01f32);
        // reference: k naive steps, carrying fresh vectors
        let mut want_p = params.clone();
        let mut want_l = 0.0f32;
        for _ in 0..k {
            let (p, l) = ref_train_step(&batch, &want_p, lr, reg);
            want_p = p;
            want_l = l;
        }
        let (got_p, got_l) = m.train_steps(&batch, &params, lr, reg, k).unwrap();
        assert_bits_eq(&got_p, &want_p, &format!("k={k} params"));
        assert_eq!(got_l.to_bits(), want_l.to_bits(), "k={k} loss");
        // and the in-place loop equals step-by-step through the public API
        let mut p2 = params.clone();
        let mut l2 = 0.0f32;
        for _ in 0..k {
            let (p, l) = m.train_step(&batch, &p2, lr, reg).unwrap();
            p2 = p;
            l2 = l;
        }
        assert_bits_eq(&got_p, &p2, &format!("k={k} vs stepwise"));
        assert_eq!(got_l.to_bits(), l2.to_bits(), "k={k} loss vs stepwise");
    }
}

#[test]
fn fused_scores_are_bit_identical_to_reference() {
    let m = common::native();
    let mut rng = Rng::new(0x5C0_2E5);
    for rows in [0usize, 1, 9, 33, 64] {
        let batch = random_batch(&mut rng, rows);
        let params: Vec<f32> = (0..33).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let got = m.scores(&batch, &params).unwrap();
        let want = ref_scores(&batch, &params);
        assert_eq!(got.len(), rows);
        assert_bits_eq(&got, &want, &format!("rows {rows}"));
    }
}

// ---------------------------------------------------------------------
// Fused frame accumulation equivalence
// ---------------------------------------------------------------------

#[test]
fn frame_accumulator_matches_decode_reference_across_presets() {
    let mut rng = Rng::new(0xACC_F2A);
    for preset in ["f32", "f16", "i8", "lean", "sparse"] {
        let wire = WireConfig::preset(preset).unwrap();
        let dim = 33;
        let baseline: Vec<f32> = (0..dim).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let frames: Vec<Frame> = (0..6)
            .map(|_| {
                // near-baseline vectors so sparse top-k has real structure
                let xs: Vec<f32> = baseline
                    .iter()
                    .map(|&b| b + (rng.f32() - 0.5) * 0.2)
                    .collect();
                wire.encode(&xs, 4, Some((3, &baseline)))
            })
            .collect();
        // reference: decode every frame, f64-accumulate in arrival order
        let mut acc = vec![0.0f64; dim];
        for fr in &frames {
            for (a, v) in acc.iter_mut().zip(fr.decode(Some(&baseline)).unwrap()) {
                *a += v as f64;
            }
        }
        let want: Vec<f32> =
            acc.iter().map(|a| (a / frames.len() as f64) as f32).collect();

        let mut fused = FrameAccumulator::new(dim);
        for fr in &frames {
            fused.add_frame(fr, Some(&baseline)).unwrap();
        }
        assert_bits_eq(&fused.mean().unwrap(), &want, preset);
    }
}

#[test]
fn masked_accumulator_matches_per_frame_decode_reference() {
    let mut rng = Rng::new(0x3A5_CED);
    let dim = 33;
    let words: Vec<Vec<i64>> = (0..5)
        .map(|_| (0..dim).map(|_| rng.next_u64() as i64).collect())
        .collect();
    let frames: Vec<Frame> = words.iter().map(|w| Frame::masked_frame(2, w)).collect();
    // reference: the pre-fusion collect path — materialize every
    // contributor's words, then wrapping-sum
    let mut want = vec![0i64; dim];
    for fr in &frames {
        for (a, v) in want.iter_mut().zip(fr.masked_values().unwrap()) {
            *a = a.wrapping_add(v);
        }
    }
    let mut fused = MaskedAccumulator::new(dim);
    for fr in &frames {
        fused.add_frame(fr).unwrap();
    }
    assert_eq!(fused.into_sum().unwrap(), want);
}

// ---------------------------------------------------------------------
// LPT scheduler: fingerprint parity under lopsided cluster sizes
// ---------------------------------------------------------------------

/// Thread counts to compare against the sequential run. CI pins the
/// suite at `SCALE_TEST_THREADS` 1 and 4; unset, it sweeps {2, 4}.
fn parity_threads() -> Vec<usize> {
    match std::env::var("SCALE_TEST_THREADS") {
        Ok(v) => vec![v.parse().expect("SCALE_TEST_THREADS must be a number")],
        Err(_) => vec![2, 4],
    }
}

#[test]
fn lpt_scheduling_keeps_fingerprints_thread_invariant_on_lopsided_clusters() {
    // No balance constraint on clustering: with 4 centroids over
    // label-skewed summaries the cluster sizes come out genuinely
    // uneven, so LPT assignment actually reorders execution relative to
    // the old shared-queue scheduler — and must still not leak into the
    // fingerprint (only merge order could, and it is pinned).
    let compute = common::native();
    let mut cfg = common::small_cfg();
    cfg.n_nodes = 26;
    cfg.partition = scale_fl::config::Partition::LabelSkew(0.3);
    cfg.cluster.balance_slack = None;
    cfg.rounds = 5;
    let cfg = cfg.normalized();
    let fp = |threads: usize| -> String {
        let mut c = cfg.clone();
        c.threads = threads;
        let mut sim = Simulation::new_parallel(c, &compute).expect("setup");
        sim.run_scale().expect("run").fingerprint()
    };
    let base = fp(1);
    for t in parity_threads() {
        assert_eq!(fp(t), base, "fingerprint diverged at threads={t}");
    }
}
