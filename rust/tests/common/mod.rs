//! Shared setup for the integration suites: the native backend handle
//! and the canonical small federation every engine-level test runs on.
//! Each test binary includes this with `mod common;` and uses the
//! subset it needs.

#![allow(dead_code)] // not every suite uses every helper

use scale_fl::config::SimConfig;
use scale_fl::runtime::compute::NativeSvm;

/// The pure-rust SVM oracle at its default dimensions — the `Send +
/// Sync` backend the parallel engine and every tier-1 suite run on.
pub fn native() -> NativeSvm {
    NativeSvm::new(NativeSvm::default_dims())
}

/// The canonical small federation (20 nodes / 4 clusters / 8 rounds,
/// seed 5): big enough that clustering, elections and checkpoint gating
/// all engage, small enough that a full three-algorithm suite stays
/// fast.
pub fn small_cfg() -> SimConfig {
    SimConfig {
        n_nodes: 20,
        n_clusters: 4,
        rounds: 8,
        local_epochs: 3,
        eval_every: 4,
        dataset_samples: 400,
        dataset_malignant: 150,
        seed: 5,
        ..Default::default()
    }
    .normalized()
}
