"""Layer-2 JAX compute graphs for the SCALE stack.

Each public function here is one AOT artifact: ``aot.py`` jits it,
lowers it to HLO text, and the rust coordinator executes it through PJRT
on the hot path. All shapes are static (see ``Dims``); variable-size
client datasets are padded + masked by the rust side.

Two model families share the same artifact interface (so the coordinator
is model-agnostic):

* **SVM** — linear SVM trained by hinge-loss + L2 subgradient descent.
  This is the paper's own workload (scikit-learn SVC on Breast Cancer
  Wisconsin ≈ linear-kernel SVC ≈ this model; see DESIGN.md §2).
* **MLP** — one-hidden-layer tanh network with logistic loss, proving the
  stack generalises beyond the paper's linear model. All matrix products
  (fwd and bwd) run through the pallas ``matmul`` kernel.

Packed parameter layout (f32 vectors, so aggregation is a masked mean
over a bank of flat vectors):

* SVM: ``[w_0..w_{F-1} | b]``                          → D = F + 1 = 33
* MLP: ``[W1 (F*H) | b1 (H) | W2 (H) | b2 (1)]``       → D = 545
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels import aggregate as agg_k
from compile.kernels import hinge as hinge_k
from compile.kernels import matmul as mm_k
from compile.kernels import scores as scores_k


@dataclasses.dataclass(frozen=True)
class Dims:
    """Static shape contract shared with the rust coordinator.

    ``batch``    rows per training/eval call (clients pad + mask to this);
    ``features`` padded feature count (WDBC's 30 → 32 for lane alignment);
    ``bank``     max rows in an aggregation bank (max cluster size + 1);
    ``hidden``   MLP hidden width.
    """

    batch: int = 64
    features: int = 32
    bank: int = 16
    hidden: int = 16

    @property
    def svm_dim(self) -> int:
        return self.features + 1

    @property
    def mlp_dim(self) -> int:
        f, h = self.features, self.hidden
        return f * h + h + h + 1


DIMS = Dims()


# --------------------------------------------------------------------------
# SVM (paper workload)
# --------------------------------------------------------------------------

def _svm_unpack(params):
    return params[:-1], params[-1:]


def svm_train_step(x, y, mask, params, lr, reg):
    """One full-batch hinge-loss subgradient step.

    Args:
      x: f32[B, F]; y: f32[B] in {-1,+1}; mask: f32[B] in {0,1};
      params: f32[F+1] packed ``[w | b]``; lr, reg: f32 scalars.

    Returns:
      (params' f32[F+1], loss f32[]) — loss is the *pre-step* regularised
      objective ``mean_hinge + reg/2 * ||w||²``, which the coordinator uses
      for checkpoint gating and convergence traces.
    """
    w, b = _svm_unpack(params)
    gw_sum, gb_sum, loss_sum, n = hinge_k.hinge_grad_sums(x, y, mask, w, b)
    n = jnp.maximum(n[0], 1.0)
    grad_w = gw_sum / n + reg * w
    grad_b = gb_sum[0] / n
    loss = loss_sum[0] / n + 0.5 * reg * jnp.sum(w * w)
    new = jnp.concatenate([w - lr * grad_w, (b - lr * grad_b)])
    return new, loss


def svm_train_loop(x, y, mask, params, lr, reg, steps):
    """`steps` full-batch hinge subgradient steps in ONE executable.

    Perf-path variant of ``svm_train_step`` (EXPERIMENTS.md §Perf): the
    coordinator's local-training inner loop (``local_epochs`` steps over
    the same padded batch) runs as a single XLA while-loop, cutting PJRT
    dispatch + host<->device transfer count by the epoch factor. ``steps``
    is a traced i32 scalar so one artifact serves every epoch setting.

    Returns (params', last pre-step loss).
    """

    def body(_, carry):
        p, _loss = carry
        return svm_train_step(x, y, mask, p, lr, reg)

    return jax.lax.fori_loop(
        0, steps, body, (params, jnp.float32(0.0))
    )


def svm_scores(x, params):
    """Decision scores f32[B] for evaluation (sign = class)."""
    w, b = _svm_unpack(params)
    return scores_k.linear_scores(x, w, b)


def svm_init(dims: Dims = DIMS):
    """Zero-initialised packed SVM parameters (deterministic)."""
    return jnp.zeros((dims.svm_dim,), jnp.float32)


# --------------------------------------------------------------------------
# MLP (generalisation workload)
# --------------------------------------------------------------------------

def _mlp_unpack(params, dims: Dims = DIMS):
    f, h = dims.features, dims.hidden
    w1 = params[: f * h].reshape(f, h)
    b1 = params[f * h : f * h + h]
    w2 = params[f * h + h : f * h + 2 * h].reshape(h, 1)
    b2 = params[f * h + 2 * h :]
    return w1, b1, w2, b2


def _mlp_forward(x, params, dims: Dims = DIMS):
    w1, b1, w2, b2 = _mlp_unpack(params, dims)
    hidden = jnp.tanh(mm_k.dense(x, w1, b1))          # [B, H] — pallas
    out = mm_k.dense(hidden, w2, b2)                  # [B, 1] — pallas
    return out[:, 0]


def _mlp_loss(params, x, y, mask, reg, dims: Dims = DIMS):
    scores = _mlp_forward(x, params, dims)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    # logistic loss on ±1 labels, masked mean
    per_row = jnp.logaddexp(0.0, -y * scores)
    data = jnp.sum(mask * per_row) / n
    return data + 0.5 * reg * jnp.sum(params * params)


def mlp_train_step(x, y, mask, params, lr, reg, dims: Dims = DIMS):
    """One full-batch gradient step on the logistic objective.

    Same interface as ``svm_train_step`` with D = ``dims.mlp_dim``; the
    backward pass runs through the pallas ``dense`` custom-VJP.
    """
    loss, grads = jax.value_and_grad(_mlp_loss)(params, x, y, mask, reg, dims)
    return params - lr * grads, loss


def mlp_train_loop(x, y, mask, params, lr, reg, steps, dims: Dims = DIMS):
    """Multi-step MLP training loop (see ``svm_train_loop``)."""

    def body(_, carry):
        p, _loss = carry
        return mlp_train_step(x, y, mask, p, lr, reg, dims)

    return jax.lax.fori_loop(
        0, steps, body, (params, jnp.float32(0.0))
    )


def mlp_scores(x, params, dims: Dims = DIMS):
    """Decision scores f32[B] (sign = class)."""
    return _mlp_forward(x, params, dims)


def mlp_init(seed: int = 0, dims: Dims = DIMS):
    """Small-scale Glorot-ish init, deterministic in ``seed``."""
    f, h = dims.features, dims.hidden
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (f, h), jnp.float32) * (1.0 / jnp.sqrt(f))
    w2 = jax.random.normal(k2, (h, 1), jnp.float32) * (1.0 / jnp.sqrt(h))
    return jnp.concatenate(
        [w1.reshape(-1), jnp.zeros((h,)), w2.reshape(-1), jnp.zeros((1,))]
    ).astype(jnp.float32)


# --------------------------------------------------------------------------
# Aggregation (eq 9 peer exchange / eq 10 driver consensus)
# --------------------------------------------------------------------------

def aggregate(bank, mask):
    """Masked mean over a bank of packed parameter vectors.

    Args:
      bank: f32[K, D] stacked parameter vectors; mask: f32[K] validity.

    Returns: f32[D].
    """
    return agg_k.masked_mean(bank, mask)
