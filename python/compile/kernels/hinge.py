"""Fused hinge-loss gradient kernel (the SVM training hot-spot).

One pallas pass over the design matrix produces everything the training
step needs:

    scores   = X @ w + b                       (per row)
    margin_r = 1 - y_r * scores_r
    active_r = mask_r * (margin_r > 0)
    gw_sum   = - sum_r  active_r * y_r * X[r, :]      (raw, un-normalised)
    gb_sum   = - sum_r  active_r * y_r
    loss_sum =   sum_r  mask_r * max(0, margin_r)
    n        =   sum_r  mask_r

The caller (layer 2, ``model.py``) finishes with the cheap scalar epilogue
``grad_w = gw_sum / n + reg * w`` so the kernel itself stays a pure
reduction and the design matrix is read exactly once (no separate
score / loss / grad passes, no HBM round-trip for the activations).

Tiling: the grid walks row blocks of ``block_rows`` (default 16) rows;
``w`` stays resident across the whole grid while X/y/mask stream through
VMEM one block at a time. Outputs are accumulated in place across grid
steps (initialised at step 0). With B=64, F=32, f32 the per-step VMEM
footprint is ~(16x32 + 3*16 + 32)*4 B ~= 2.4 KiB — far under any real
VMEM budget; the block shape is chosen for 8-sublane alignment rather
than capacity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hinge_kernel(x_ref, y_ref, m_ref, w_ref, b_ref,
                  gw_ref, gb_ref, loss_ref, n_ref):
    """One grid step: accumulate hinge statistics for a block of rows."""
    step = pl.program_id(0)

    x = x_ref[...]            # [BR, F]
    y = y_ref[...]            # [BR]
    m = m_ref[...]            # [BR]
    w = w_ref[...]            # [F]
    b = b_ref[0]

    scores = x @ w + b                            # [BR]
    margin = 1.0 - y * scores                     # [BR]
    active = m * (margin > 0.0).astype(x.dtype)   # [BR]
    coef = active * y                             # [BR]

    gw_part = -(coef @ x)                         # [F]
    gb_part = -jnp.sum(coef)
    loss_part = jnp.sum(m * jnp.maximum(margin, 0.0))
    n_part = jnp.sum(m)

    @pl.when(step == 0)
    def _init():
        gw_ref[...] = jnp.zeros_like(gw_ref)
        gb_ref[...] = jnp.zeros_like(gb_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    gw_ref[...] += gw_part
    gb_ref[0] += gb_part
    loss_ref[0] += loss_part
    n_ref[0] += n_part


@functools.partial(jax.jit, static_argnames=("block_rows",))
def hinge_grad_sums(x, y, mask, w, b, *, block_rows: int = 16):
    """Raw hinge-loss reduction sums via the fused pallas kernel.

    Args:
      x:    f32[B, F] design matrix (padding rows arbitrary).
      y:    f32[B] labels in {-1, +1} (padding rows arbitrary).
      mask: f32[B] row validity in {0, 1}.
      w:    f32[F] weight vector.
      b:    f32[1] bias.
      block_rows: rows per grid step; must divide B.

    Returns:
      (gw_sum f32[F], gb_sum f32[1], loss_sum f32[1], n f32[1]).
    """
    batch, feat = x.shape
    if batch % block_rows != 0:
        raise ValueError(f"block_rows {block_rows} must divide batch {batch}")
    grid = (batch // block_rows,)

    return pl.pallas_call(
        _hinge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((feat,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((feat,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((feat,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=True,
    )(x, y, mask, w, b)
