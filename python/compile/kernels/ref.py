"""Pure-``jax.numpy`` oracles for every pallas kernel.

These are the correctness ground truth: identical math to the kernels,
written with no pallas machinery whatsoever. The pytest suite (driven by
``hypothesis`` over shapes / values / masks) asserts ``allclose`` between
each kernel and its oracle, and the AOT artifacts are lowered from the
kernel path only after that gate passes.
"""

from __future__ import annotations

import jax.numpy as jnp


def hinge_grad_sums_ref(x, y, mask, w, b):
    """Oracle for ``hinge.hinge_grad_sums`` (same raw, un-normalised sums)."""
    scores = x @ w + b[0]
    margin = 1.0 - y * scores
    active = mask * (margin > 0.0).astype(x.dtype)
    coef = active * y
    gw = -(coef @ x)
    gb = -jnp.sum(coef)
    loss = jnp.sum(mask * jnp.maximum(margin, 0.0))
    n = jnp.sum(mask)
    return gw, jnp.array([gb]), jnp.array([loss]), jnp.array([n])


def matmul_ref(a, b):
    """Oracle for ``matmul.matmul``."""
    return a @ b


def dense_ref(x, w, b):
    """Oracle for ``matmul.dense`` (forward)."""
    return x @ w + b


def dense_grads_ref(x, w, g):
    """Oracle for the dense backward products."""
    return g @ w.T, x.T @ g, jnp.sum(g, axis=0)


def masked_mean_ref(bank, mask):
    """Oracle for ``aggregate.masked_mean``."""
    total = mask @ bank
    count = jnp.maximum(jnp.sum(mask), 1.0)
    return total / count


def linear_scores_ref(x, w, b):
    """Oracle for ``scores.linear_scores``."""
    return x @ w + b[0]
