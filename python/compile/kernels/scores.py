"""Linear decision-score kernel: ``scores = X @ w + b``.

Used by the evaluation artifact (the rust coordinator turns raw scores
into accuracy / precision / recall / F1 / ROC-AUC, which need the full
score vector, not just predictions). Tiled over row blocks like the hinge
kernel so X streams through VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scores_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = x_ref[...] @ w_ref[...] + b_ref[0]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def linear_scores(x, w, b, *, block_rows: int = 16):
    """Decision scores for a block of rows.

    Args:
      x: f32[B, F]; w: f32[F]; b: f32[1].
      block_rows: rows per grid step; must divide B.

    Returns: f32[B] raw margins (sign = predicted class).
    """
    batch, feat = x.shape
    if batch % block_rows != 0:
        raise ValueError(f"block_rows {block_rows} must divide batch {batch}")

    return pl.pallas_call(
        _scores_kernel,
        grid=(batch // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, feat), lambda i: (i, 0)),
            pl.BlockSpec((feat,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), x.dtype),
        interpret=True,
    )(x, w, b)
