"""Tiled pallas matmul and a custom-VJP dense layer built on it.

The MLP model variant routes *all* of its matrix products — forward
activations and both backward products — through ``matmul`` so the whole
fwd/bwd graph is pallas-kernel compute (``jax.custom_vjp`` supplies the
differentiation rule because ``pallas_call`` has none of its own).

Tiling: grid over (M-tiles, N-tiles); the contraction dimension K is kept
whole per tile (K <= 64 everywhere in this model family, so a full K strip
of both operands fits VMEM comfortably: with bm=bn=16, K=64, f32 the three
resident tiles are 16x64 + 64x16 + 16x16 floats ~= 9 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] @ b_ref[...]


def _pick_tile(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= ``want`` (tile size helper)."""
    t = min(want, dim)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(a, b, *, bm: int = 16, bn: int = 16):
    """``a @ b`` via the tiled pallas kernel.

    Args:
      a: f32[M, K]
      b: f32[K, N]
      bm, bn: requested output tile sizes (clamped to divisors of M / N).

    Returns: f32[M, N]
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    tm, tn = _pick_tile(m, bm), _pick_tile(n, bn)

    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def dense(x, w, b):
    """Dense layer ``x @ w + b`` with pallas compute in fwd and bwd."""
    return matmul(x, w) + b


def _dense_fwd(x, w, b):
    return dense(x, w, b), (x, w)


def _dense_bwd(res, g):
    x, w = res
    dx = matmul(g, w.T)          # [M, K]
    dw = matmul(x.T, g)          # [K, N]
    db = jnp.sum(g, axis=0)      # [N]
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
