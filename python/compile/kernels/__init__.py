"""Layer-1 Pallas kernels for the SCALE federated-learning stack.

Every kernel here is authored with ``jax.experimental.pallas`` and lowered
with ``interpret=True`` so the resulting HLO contains plain XLA ops that
the rust PJRT CPU client can execute (real-TPU Mosaic custom-calls cannot
run on the CPU plugin; see DESIGN.md §Hardware-Adaptation).

Kernels
-------
``hinge.hinge_grad_sums``
    Fused single-pass hinge-loss statistics for the linear SVM: raw
    gradient sums, loss sum and active-row count, tiled over row blocks.
``matmul.matmul`` / ``matmul.dense``
    Tiled matmul kernel and a ``jax.custom_vjp`` dense layer whose forward
    *and* backward passes route through the kernel (used by the MLP).
``aggregate.masked_mean``
    Masked mean over a stacked bank of parameter vectors — the compute
    core of both the peer-exchange average (paper eq 9) and the driver's
    consensus aggregation (paper eq 10).
``scores.linear_scores``
    Decision-score kernel ``X @ w + b`` for evaluation.

``ref.py`` holds the pure-``jax.numpy`` oracles the pytest suite checks
every kernel against (exact same math, no pallas).
"""

from . import aggregate, hinge, matmul, ref, scores  # noqa: F401

__all__ = ["aggregate", "hinge", "matmul", "ref", "scores"]
