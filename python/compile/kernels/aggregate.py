"""Masked mean over a stacked bank of parameter vectors.

This is the compute core of both sides of the paper's Hybrid Decentralized
Aggregation Protocol:

* peer exchange (eq 9): a node averages its own weights with the weights
  received from its |N_i| peers — a masked mean over a bank with
  |N_i| + 1 valid rows;
* driver consensus (eq 10): the elected driver averages the post-exchange
  weights of every live node in its cluster.

The bank is a fixed-shape f32[K, D] buffer (K = max cluster size) with a
validity mask so one AOT artifact serves every cluster size; D is the
packed parameter dimension. Single-block kernel: with K=16, D<=608, f32
the whole bank is ~38 KiB — one VMEM-resident tile, so tiling over D would
only add grid overhead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_mean_kernel(bank_ref, mask_ref, o_ref):
    bank = bank_ref[...]          # [K, D]
    mask = mask_ref[...]          # [K]
    total = mask @ bank           # [D]  (weighted row-sum)
    count = jnp.maximum(jnp.sum(mask), 1.0)
    o_ref[...] = total / count


@jax.jit
def masked_mean(bank, mask):
    """Mean of the rows of ``bank`` selected by ``mask``.

    Args:
      bank: f32[K, D] stacked parameter vectors (invalid rows arbitrary).
      mask: f32[K] row validity in {0, 1}.

    Returns: f32[D]; zeros-safe (empty mask divides by 1, returning 0s
      only if the bank rows were 0 — callers guarantee >= 1 valid row).
    """
    k, d = bank.shape
    return pl.pallas_call(
        _masked_mean_kernel,
        in_specs=[
            pl.BlockSpec((k, d), lambda: (0, 0)),
            pl.BlockSpec((k,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), bank.dtype),
        interpret=True,
    )(bank, mask)
