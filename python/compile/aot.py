"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly. Every function is
lowered with ``return_tuple=True`` so the rust runtime uniformly unpacks
an output tuple.

Alongside the ``*.hlo.txt`` files we emit ``manifest.json`` describing the
I/O contract (names, shapes, dtypes, packed-parameter dims) that the rust
runtime validates at load time — a wrong shape fails fast at startup, not
mid-round.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.model import DIMS


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (the rust-loadable form)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _f32(shape):
    return {"shape": list(shape), "dtype": "f32"}


def _i32(shape):
    return {"shape": list(shape), "dtype": "i32"}


def build_entries(dims=DIMS):
    """(name, fn, example-args, manifest-io) for every artifact."""
    b, f, k = dims.batch, dims.features, dims.bank
    ds, dm = dims.svm_dim, dims.mlp_dim

    x = _spec((b, f))
    yv = _spec((b,))
    maskv = _spec((b,))
    scalar = _spec(())
    steps = _spec((), jnp.int32)

    def tup(fn):
        # lowered with return_tuple=True; make single outputs explicit tuples
        def wrapped(*a):
            out = fn(*a)
            return out if isinstance(out, tuple) else (out,)

        return wrapped

    entries = [
        {
            "name": "svm_train_step",
            "fn": tup(model.svm_train_step),
            "args": (x, yv, maskv, _spec((ds,)), scalar, scalar),
            "inputs": [
                ("x", _f32((b, f))), ("y", _f32((b,))), ("mask", _f32((b,))),
                ("params", _f32((ds,))), ("lr", _f32(())), ("reg", _f32(())),
            ],
            "outputs": [("params", _f32((ds,))), ("loss", _f32(()))],
        },
        {
            "name": "svm_train_loop",
            "fn": tup(model.svm_train_loop),
            "args": (x, yv, maskv, _spec((ds,)), scalar, scalar, steps),
            "inputs": [
                ("x", _f32((b, f))), ("y", _f32((b,))), ("mask", _f32((b,))),
                ("params", _f32((ds,))), ("lr", _f32(())), ("reg", _f32(())),
                ("steps", _i32(())),
            ],
            "outputs": [("params", _f32((ds,))), ("loss", _f32(()))],
        },
        {
            "name": "svm_scores",
            "fn": tup(model.svm_scores),
            "args": (x, _spec((ds,))),
            "inputs": [("x", _f32((b, f))), ("params", _f32((ds,)))],
            "outputs": [("scores", _f32((b,)))],
        },
        {
            "name": "mlp_train_step",
            "fn": tup(model.mlp_train_step),
            "args": (x, yv, maskv, _spec((dm,)), scalar, scalar),
            "inputs": [
                ("x", _f32((b, f))), ("y", _f32((b,))), ("mask", _f32((b,))),
                ("params", _f32((dm,))), ("lr", _f32(())), ("reg", _f32(())),
            ],
            "outputs": [("params", _f32((dm,))), ("loss", _f32(()))],
        },
        {
            "name": "mlp_train_loop",
            "fn": tup(model.mlp_train_loop),
            "args": (x, yv, maskv, _spec((dm,)), scalar, scalar, steps),
            "inputs": [
                ("x", _f32((b, f))), ("y", _f32((b,))), ("mask", _f32((b,))),
                ("params", _f32((dm,))), ("lr", _f32(())), ("reg", _f32(())),
                ("steps", _i32(())),
            ],
            "outputs": [("params", _f32((dm,))), ("loss", _f32(()))],
        },
        {
            "name": "mlp_scores",
            "fn": tup(model.mlp_scores),
            "args": (x, _spec((dm,))),
            "inputs": [("x", _f32((b, f))), ("params", _f32((dm,)))],
            "outputs": [("scores", _f32((b,)))],
        },
        {
            "name": "aggregate_svm",
            "fn": tup(model.aggregate),
            "args": (_spec((k, ds)), _spec((k,))),
            "inputs": [("bank", _f32((k, ds))), ("mask", _f32((k,)))],
            "outputs": [("mean", _f32((ds,)))],
        },
        {
            "name": "aggregate_mlp",
            "fn": tup(model.aggregate),
            "args": (_spec((k, dm)), _spec((k,))),
            "inputs": [("bank", _f32((k, dm))), ("mask", _f32((k,)))],
            "outputs": [("mean", _f32((dm,)))],
        },
    ]
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", help="comma-separated artifact names", default="")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = {s for s in args.only.split(",") if s}

    dims = DIMS
    manifest = {
        "dims": {
            "batch": dims.batch,
            "features": dims.features,
            "raw_features": 30,
            "bank": dims.bank,
            "hidden": dims.hidden,
            "svm_dim": dims.svm_dim,
            "mlp_dim": dims.mlp_dim,
        },
        "artifacts": {},
    }

    for e in build_entries(dims):
        if only and e["name"] not in only:
            continue
        lowered = jax.jit(e["fn"]).lower(*e["args"])
        text = to_hlo_text(lowered)
        fname = f"{e['name']}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][e["name"]] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [{"name": n, **io} for n, io in e["inputs"]],
            "outputs": [{"name": n, **io} for n, io in e["outputs"]],
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
