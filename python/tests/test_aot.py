"""AOT path: lowering produces parseable single-module HLO text and a
manifest whose shapes match the model contract."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot
from compile.model import DIMS


def test_entries_cover_all_artifacts():
    names = {e["name"] for e in aot.build_entries()}
    assert names == {
        "svm_train_step", "svm_train_loop", "svm_scores",
        "mlp_train_step", "mlp_train_loop", "mlp_scores",
        "aggregate_svm", "aggregate_mlp",
    }


def test_lowered_hlo_text_shape():
    import jax

    entry = next(e for e in aot.build_entries() if e["name"] == "aggregate_svm")
    lowered = jax.jit(entry["fn"]).lower(*entry["args"])
    text = aot.to_hlo_text(lowered)
    # HLO text module header + ENTRY computation
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text
    # static shapes visible in the signature
    assert f"f32[{DIMS.bank},{DIMS.svm_dim}]" in text
    # exactly one module (rust loader expects a single module per file)
    assert text.count("HloModule") == 1


def test_cli_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "arts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--only", "aggregate_svm,svm_scores"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert set(manifest["artifacts"]) == {"aggregate_svm", "svm_scores"}
    dims = manifest["dims"]
    assert dims["batch"] == DIMS.batch
    assert dims["svm_dim"] == DIMS.svm_dim
    assert dims["raw_features"] == 30
    for name, spec in manifest["artifacts"].items():
        text = (out / spec["file"]).read_text()
        assert text.startswith("HloModule"), name
        import hashlib

        assert spec["sha256"] == hashlib.sha256(text.encode()).hexdigest(), name
        assert spec["inputs"] and spec["outputs"], name


def test_manifest_io_specs_match_model_dims():
    entries = {e["name"]: e for e in aot.build_entries()}
    ts = entries["svm_train_step"]
    shapes = {n: io["shape"] for n, io in ts["inputs"]}
    assert shapes["x"] == [DIMS.batch, DIMS.features]
    assert shapes["params"] == [DIMS.svm_dim]
    assert shapes["lr"] == []
    outs = {n: io["shape"] for n, io in ts["outputs"]}
    assert outs["params"] == [DIMS.svm_dim]
    assert outs["loss"] == []

    ag = entries["aggregate_mlp"]
    shapes = {n: io["shape"] for n, io in ag["inputs"]}
    assert shapes["bank"] == [DIMS.bank, DIMS.mlp_dim]


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    ),
    reason="artifacts not built",
)
def test_existing_artifacts_hash_clean():
    """`make artifacts` output on disk must match its manifest (the rust
    runtime enforces the same at load time)."""
    import hashlib

    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = json.loads(open(os.path.join(root, "manifest.json")).read())
    assert len(manifest["artifacts"]) == 8
    for name, spec in manifest["artifacts"].items():
        text = open(os.path.join(root, spec["file"])).read()
        assert spec["sha256"] == hashlib.sha256(text.encode()).hexdigest(), name
