"""L2 behaviour: training dynamics, padding inertness, packing, shapes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.model import DIMS

jax.config.update("jax_platform_name", "cpu")

F32 = np.float32


def separable_batch(seed=0, n_valid=48):
    """Linearly separable batch in the AOT contract shape."""
    r = np.random.RandomState(seed)
    b, f = DIMS.batch, DIMS.features
    x = np.zeros((b, f), F32)
    y = np.zeros(b, F32)
    mask = np.zeros(b, F32)
    labels = r.choice([-1.0, 1.0], n_valid)
    x[:n_valid] = r.normal(0, 0.3, (n_valid, f))
    x[:n_valid, 0] += labels * 1.5
    y[:n_valid] = labels
    mask[:n_valid] = 1.0
    return jnp.array(x), jnp.array(y), jnp.array(mask)


def test_svm_loss_decreases_and_classifies():
    x, y, mask = separable_batch()
    params = model.svm_init()
    first = None
    for _ in range(120):
        params, loss = model.svm_train_step(x, y, mask, params, 0.1, 0.001)
        first = first if first is not None else float(loss)
    final_loss = float(model.svm_train_step(x, y, mask, params, 0.1, 0.001)[1])
    assert final_loss < first * 0.5, (first, final_loss)
    scores = model.svm_scores(x, params)
    preds = np.sign(np.asarray(scores))[:48]
    labels = np.asarray(y)[:48]
    acc = float((preds == labels).mean())
    assert acc > 0.95, acc


def test_svm_padding_rows_inert():
    x, y, mask = separable_batch()
    params = model.svm_init()
    # poison the masked-out region
    x2 = np.asarray(x).copy()
    x2[48:] = 1e6
    y2 = np.asarray(y).copy()
    y2[48:] = 1.0
    p1, l1 = model.svm_train_step(x, y, mask, params, 0.1, 0.01)
    p2, l2 = model.svm_train_step(jnp.array(x2), jnp.array(y2), mask, params, 0.1, 0.01)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-6)
    assert float(l1) == float(l2)


def test_svm_padded_feature_columns_stay_zero():
    x, y, mask = separable_batch()
    # zero the padding columns (30, 31) as the rust loader guarantees
    x = x.at[:, 30:].set(0.0)
    params = model.svm_init()
    for _ in range(20):
        params, _ = model.svm_train_step(x, y, mask, params, 0.1, 0.001)
    w_pad = np.asarray(params)[30:32]
    np.testing.assert_allclose(w_pad, 0.0, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mlp_gradient_step_decreases_loss(seed):
    x, y, mask = separable_batch(seed)
    params = model.mlp_init(seed)
    _, loss0 = model.mlp_train_step(x, y, mask, params, 0.0, 0.0)  # no-op step
    p = params
    for _ in range(60):
        p, loss = model.mlp_train_step(x, y, mask, p, 0.2, 0.0)
    assert float(loss) < float(loss0), (float(loss0), float(loss))


def test_mlp_packing_roundtrip():
    params = model.mlp_init(3)
    assert params.shape == (DIMS.mlp_dim,)
    w1, b1, w2, b2 = model._mlp_unpack(params)
    assert w1.shape == (DIMS.features, DIMS.hidden)
    assert b1.shape == (DIMS.hidden,)
    assert w2.shape == (DIMS.hidden, 1)
    assert b2.shape == (1,)
    repacked = jnp.concatenate([w1.reshape(-1), b1, w2.reshape(-1), b2])
    np.testing.assert_array_equal(np.asarray(repacked), np.asarray(params))


def test_aggregate_is_masked_mean():
    r = np.random.RandomState(0)
    bank = r.normal(size=(DIMS.bank, DIMS.svm_dim)).astype(F32)
    mask = np.zeros(DIMS.bank, F32)
    mask[:5] = 1.0
    out = model.aggregate(jnp.array(bank), jnp.array(mask))
    np.testing.assert_allclose(np.asarray(out), bank[:5].mean(0), rtol=1e-5, atol=1e-6)


def test_eq9_peer_average_via_aggregate():
    # eq 9 with |N_i| = 2: mean of own + two peer vectors
    own = np.full(DIMS.svm_dim, 1.0, F32)
    p1 = np.full(DIMS.svm_dim, 4.0, F32)
    p2 = np.full(DIMS.svm_dim, 7.0, F32)
    bank = np.zeros((DIMS.bank, DIMS.svm_dim), F32)
    bank[0], bank[1], bank[2] = own, p1, p2
    mask = np.zeros(DIMS.bank, F32)
    mask[:3] = 1.0
    out = np.asarray(model.aggregate(jnp.array(bank), jnp.array(mask)))
    np.testing.assert_allclose(out, 4.0, rtol=1e-6)


def test_dims_contract():
    assert DIMS.svm_dim == DIMS.features + 1 == 33
    assert DIMS.mlp_dim == DIMS.features * DIMS.hidden + 2 * DIMS.hidden + 1 == 545
    assert DIMS.batch % 16 == 0  # hinge kernel block divisibility


def test_svm_train_loop_matches_repeated_steps():
    import jax.numpy as jnp
    from compile import model
    x, y, mask = separable_batch(3)
    params = model.svm_init()
    p_loop, loss_loop = model.svm_train_loop(x, y, mask, params, 0.1, 0.001, 7)
    p_iter = params
    loss_iter = None
    for _ in range(7):
        p_iter, loss_iter = model.svm_train_step(x, y, mask, p_iter, 0.1, 0.001)
    np.testing.assert_allclose(
        np.asarray(p_loop), np.asarray(p_iter), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(float(loss_loop), float(loss_iter), rtol=1e-5)


def test_mlp_train_loop_matches_repeated_steps():
    import jax.numpy as jnp
    from compile import model
    x, y, mask = separable_batch(5)
    params = model.mlp_init(2)
    p_loop, _ = model.mlp_train_loop(x, y, mask, params, 0.1, 0.0, 4)
    p_iter = params
    for _ in range(4):
        p_iter, _ = model.mlp_train_step(x, y, mask, p_iter, 0.1, 0.0)
    np.testing.assert_allclose(
        np.asarray(p_loop), np.asarray(p_iter), rtol=1e-4, atol=1e-5
    )


def test_train_loop_zero_steps_is_identity():
    from compile import model
    x, y, mask = separable_batch(1)
    params = model.svm_init() + 0.1
    p, loss = model.svm_train_loop(x, y, mask, params, 0.1, 0.001, 0)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(params))
    assert float(loss) == 0.0
