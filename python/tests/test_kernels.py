"""L1 correctness: every pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes, masks, and value ranges; assertions are
``allclose`` at f32 tolerances. This gate runs before `make artifacts`
trusts the kernels enough to lower them.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate, hinge, matmul, ref, scores

jax.config.update("jax_platform_name", "cpu")

F32 = np.float32
ATOL = 1e-4
RTOL = 1e-4


def rng_arrays(seed, *shapes, scale=2.0):
    r = np.random.RandomState(seed)
    return [r.uniform(-scale, scale, s).astype(F32) for s in shapes]


def close(a, b, what=""):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=RTOL, atol=ATOL,
                               err_msg=what)


# -------------------------------------------------------------------------
# hinge kernel
# -------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    rows=st.sampled_from([16, 32, 64]),
    feat=st.sampled_from([8, 32]),
    block=st.sampled_from([8, 16]),
    mask_frac=st.floats(0.0, 1.0),
)
def test_hinge_matches_ref(seed, rows, feat, block, mask_frac):
    (x,) = rng_arrays(seed, (rows, feat))
    r = np.random.RandomState(seed + 1)
    y = r.choice([-1.0, 1.0], rows).astype(F32)
    mask = (r.uniform(0, 1, rows) < mask_frac).astype(F32)
    w = r.uniform(-1, 1, feat).astype(F32)
    b = r.uniform(-1, 1, 1).astype(F32)

    got = hinge.hinge_grad_sums(x, y, mask, w, b, block_rows=block)
    want = ref.hinge_grad_sums_ref(x, y, mask, w, b)
    for g, e, name in zip(got, want, ["gw", "gb", "loss", "n"]):
        close(g, e, name)


def test_hinge_fully_masked_is_zero():
    x, = rng_arrays(0, (64, 32))
    y = np.ones(64, F32)
    mask = np.zeros(64, F32)
    w = np.zeros(32, F32)
    b = np.zeros(1, F32)
    gw, gb, loss, n = hinge.hinge_grad_sums(x, y, mask, w, b)
    assert float(jnp.abs(gw).max()) == 0.0
    assert float(gb[0]) == 0.0 and float(loss[0]) == 0.0 and float(n[0]) == 0.0


def test_hinge_rejects_bad_block():
    x, = rng_arrays(0, (64, 32))
    with pytest.raises(ValueError):
        hinge.hinge_grad_sums(x, x[:, 0], x[:, 0], x[0], x[0, :1], block_rows=7)


def test_hinge_active_margin_boundary():
    # rows exactly at margin 1 - y*s = 0 are INACTIVE (strict >)
    x = np.zeros((16, 8), F32)
    x[:, 0] = 1.0
    y = np.ones(16, F32)
    w = np.zeros(8, F32)
    w[0] = 1.0  # scores = 1 → margin = 0
    mask = np.ones(16, F32)
    b = np.zeros(1, F32)
    gw, gb, loss, n = hinge.hinge_grad_sums(x, y, mask, w, b, block_rows=8)
    close(gw, np.zeros(8), "gw at boundary")
    assert float(loss[0]) == 0.0
    assert float(n[0]) == 16.0


# -------------------------------------------------------------------------
# matmul / dense
# -------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from([8, 16, 64]),
    k=st.sampled_from([8, 32, 64]),
    n=st.sampled_from([1, 16, 32]),
)
def test_matmul_matches_ref(seed, m, k, n):
    a, b = rng_arrays(seed, (m, k), (k, n))
    close(matmul.matmul(a, b), ref.matmul_ref(a, b), "matmul")


def test_matmul_shape_mismatch():
    a, b = rng_arrays(0, (8, 4), (5, 8))
    with pytest.raises(ValueError):
        matmul.matmul(a, b)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dense_forward_and_grads_match_ref(seed):
    x, w = rng_arrays(seed, (16, 8), (8, 4))
    b = rng_arrays(seed + 1, (4,))[0]
    close(matmul.dense(x, w, b), ref.dense_ref(x, w, b), "dense fwd")

    # backward: compare custom-vjp grads against jnp autodiff of the ref
    def loss_k(x, w, b):
        return jnp.sum(jnp.tanh(matmul.dense(x, w, b)) ** 2)

    def loss_r(x, w, b):
        return jnp.sum(jnp.tanh(ref.dense_ref(x, w, b)) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, w, b)
    for a_, b_, name in zip(gk, gr, ["dx", "dw", "db"]):
        close(a_, b_, name)


# -------------------------------------------------------------------------
# masked mean
# -------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.sampled_from([4, 16]),
    d=st.sampled_from([33, 64, 545]),
    valid=st.integers(1, 16),
)
def test_masked_mean_matches_ref(seed, k, d, valid):
    bank, = rng_arrays(seed, (k, d))
    mask = np.zeros(k, F32)
    mask[: min(valid, k)] = 1.0
    close(aggregate.masked_mean(bank, mask), ref.masked_mean_ref(bank, mask), "mean")


def test_masked_mean_single_row_identity():
    bank, = rng_arrays(3, (16, 33))
    mask = np.zeros(16, F32)
    mask[7] = 1.0
    close(aggregate.masked_mean(bank, mask), bank[7], "single row")


def test_masked_mean_empty_mask_is_safe():
    bank, = rng_arrays(4, (8, 16))
    out = aggregate.masked_mean(bank, np.zeros(8, F32))
    assert np.all(np.isfinite(np.asarray(out)))


# -------------------------------------------------------------------------
# linear scores
# -------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), block=st.sampled_from([8, 16, 32]))
def test_linear_scores_matches_ref(seed, block):
    x, = rng_arrays(seed, (64, 32))
    w = rng_arrays(seed + 1, (32,))[0]
    b = rng_arrays(seed + 2, (1,))[0]
    close(
        scores.linear_scores(x, w, b, block_rows=block),
        ref.linear_scores_ref(x, w, b),
        "scores",
    )
