//! Quickstart: the smallest complete SCALE run.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Builds a 20-node federation on synthetic Breast Cancer Wisconsin data,
//! forms 4 clusters from encrypted client summaries, runs 10 HDAP rounds
//! through the AOT-compiled JAX/Pallas artifacts (falls back to the
//! pure-rust SVM oracle when `artifacts/` is absent), and prints the
//! headline comparison against the FedAvg baseline.

use anyhow::Result;

use scale_fl::config::SimConfig;
use scale_fl::runtime::compute::{ModelCompute, NativeSvm};
use scale_fl::sim::Simulation;

#[cfg(feature = "pjrt")]
fn backend() -> Result<Box<dyn ModelCompute>> {
    use scale_fl::runtime::compute::PjrtModel;
    use scale_fl::runtime::manifest::ModelKind;
    use scale_fl::runtime::Runtime;
    use std::path::Path;
    use std::rc::Rc;

    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = Rc::new(Runtime::open(dir)?);
        rt.warm_up()?;
        println!("backend: PJRT (AOT JAX/Pallas artifacts)");
        Ok(Box::new(PjrtModel::new(rt, ModelKind::Svm)))
    } else {
        println!("backend: native rust oracle (run `make artifacts` for PJRT)");
        Ok(Box::new(NativeSvm::new(NativeSvm::default_dims())))
    }
}

#[cfg(not(feature = "pjrt"))]
fn backend() -> Result<Box<dyn ModelCompute>> {
    println!("backend: native rust oracle (build with --features pjrt for PJRT)");
    Ok(Box::new(NativeSvm::new(NativeSvm::default_dims())))
}

fn main() -> Result<()> {
    let compute = backend()?;
    let cfg = SimConfig {
        n_nodes: 20,
        n_clusters: 4,
        rounds: 10,
        eval_every: 2,
        seed: 7,
        ..Default::default()
    }
    .normalized();

    // --- SCALE ---
    let mut sim = Simulation::new(cfg.clone(), compute.as_ref())?;
    let scale = sim.run_scale()?;

    // --- FedAvg baseline on the identical federation ---
    let mut sim = Simulation::new(cfg, compute.as_ref())?;
    let grouping = sim.scale_grouping()?;
    let fedavg = sim.run_fedavg(Some(grouping))?;

    println!("\n          |  SCALE | FedAvg");
    println!("updates   | {:>6} | {:>6}", scale.total_updates(), fedavg.total_updates());
    println!(
        "accuracy  | {:>6.3} | {:>6.3}",
        scale.final_metrics.accuracy, fedavg.final_metrics.accuracy
    );
    println!(
        "f1        | {:>6.3} | {:>6.3}",
        scale.final_metrics.f1, fedavg.final_metrics.f1
    );
    println!(
        "latency   | {:>4.0}ms | {:>4.0}ms",
        scale.total_latency_ms(),
        fedavg.total_latency_ms()
    );
    println!(
        "energy    | {:>5.1}J | {:>5.1}J",
        scale.total_energy_j(),
        fedavg.total_energy_j()
    );
    println!(
        "\nSCALE cut global updates {:.1}x at Δaccuracy {:+.3}",
        fedavg.total_updates() as f64 / scale.total_updates().max(1) as f64,
        scale.final_metrics.accuracy - fedavg.final_metrics.accuracy
    );
    Ok(())
}
