//! Failover demo: driver failures, health detection, Algorithm-4
//! re-election, and checkpoint-based continuity.
//!
//! Sweeps the per-round node failure probability and shows that SCALE
//! keeps converging: dead drivers are detected by the health monitor and
//! replaced by the weighted election of eq 11, while the cluster model
//! survives in the driver's checkpoint store.
//!
//! ```bash
//! cargo run --release --example failover_demo
//! ```

use anyhow::Result;

use scale_fl::config::SimConfig;
use scale_fl::netsim::MsgKind;
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;

fn main() -> Result<()> {
    let compute = NativeSvm::new(NativeSvm::default_dims());

    println!("failure_p | elections | ballots | live(min) | updates | final acc");
    for &p in &[0.0, 0.05, 0.1, 0.2, 0.35] {
        let cfg = SimConfig {
            n_nodes: 40,
            n_clusters: 5,
            rounds: 20,
            node_failure_prob: p,
            node_recovery_prob: 0.5,
            eval_every: 20,
            seed: 11,
            ..Default::default()
        }
        .normalized();
        let mut sim = Simulation::new(cfg, &compute)?;
        let report = sim.run_scale()?;
        let elections: u64 = report.clusters.iter().map(|c| c.elections).sum();
        let min_live = report.rounds.iter().map(|r| r.live_nodes).min().unwrap_or(0);
        println!(
            "{:>9.2} | {:>9} | {:>7} | {:>9} | {:>7} | {:.3}",
            p,
            elections,
            report.ledger.get(&MsgKind::Election).map_or(0, |t| t.count),
            min_live,
            report.total_updates(),
            report.final_metrics.accuracy,
        );
    }

    println!("\nEven at 35% per-round node failure the federation re-elects");
    println!("drivers and converges — the paper's robustness claim (§3.4).");
    Ok(())
}
