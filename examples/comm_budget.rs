//! Communication budget: what each wire codec costs on the wire.
//!
//! ```bash
//! cargo run --release --example comm_budget
//! ```
//!
//! Runs the same 40-node SCALE federation under every wire preset —
//! `f32` passthrough (lossless, the default), `f16`, `i8`, and the
//! `lean` i8+delta+top-k setup — and prints the per-round bytes-on-wire
//! table the README quotes, plus a demonstration of server-side
//! dequantize-accumulate over int8 uploads.

use anyhow::Result;

use scale_fl::aggregation::dequantize_accumulate;
use scale_fl::config::SimConfig;
use scale_fl::quant::QuantVec;
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;
use scale_fl::wire::WireConfig;

fn main() -> Result<()> {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let base = SimConfig {
        n_nodes: 40,
        n_clusters: 5,
        rounds: 12,
        eval_every: 12,
        dataset_samples: 800,
        dataset_malignant: 300,
        seed: 11,
        ..Default::default()
    }
    .normalized();

    println!("wire codec comparison — 40 nodes / 5 clusters / 12 rounds\n");
    println!("codec        | param KB | KB/round | reduction | updates | final acc");
    let mut f32_bytes = 0u64;
    for preset in ["lossless", "f16", "i8", "lean"] {
        let wire = WireConfig::preset(preset)?;
        let mut cfg = base.clone();
        cfg.wire = wire;
        let mut sim = Simulation::new(cfg, &compute)?;
        let report = sim.run_scale()?;
        let bytes = report.param_path_bytes();
        if preset == "lossless" {
            f32_bytes = bytes;
        }
        println!(
            "{:<12} | {:>8.1} | {:>8.2} | {:>8.2}x | {:>7} | {:.3}",
            wire.label(),
            bytes as f64 / 1e3,
            bytes as f64 / 1e3 / base.rounds as f64,
            f32_bytes as f64 / bytes.max(1) as f64,
            report.total_updates(),
            report.final_metrics.accuracy,
        );
    }

    // --- server-side dequantize-accumulate -------------------------------
    // When drivers upload int8 frames, the server folds them into the
    // global model without materializing each dequantized vector: the
    // per-tensor scale/zero-point applies inline during accumulation.
    println!("\ndequantize-accumulate over 5 quantized driver uploads:");
    let uploads: Vec<QuantVec> = (0..5)
        .map(|c| {
            let params: Vec<f32> =
                (0..8).map(|i| (i as f32 * 0.3 + c as f32).sin()).collect();
            QuantVec::encode(&params)
        })
        .collect();
    let fused = dequantize_accumulate(&uploads)?;
    let wire_bytes: u64 = uploads.iter().map(|q| q.wire_bytes()).sum();
    println!("  fused global model: {fused:.3?}");
    println!("  {} payload bytes vs {} as raw f32 vectors", wire_bytes, 5 * 8 * 4);
    Ok(())
}
