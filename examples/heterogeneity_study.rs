//! Heterogeneity study: the paper's title restricts SCALE to a
//! *homogeneous* environment — this example probes what actually breaks
//! as the fleet becomes heterogeneous.
//!
//! Sweeps the device-spread knob from 0 (identical hardware) to 0.8
//! (wildly mixed fleet) and reports: accuracy, round latency (stragglers
//! dominate a synchronous round), driver stability, and how much the
//! performance-index clustering + weighted election compensate.
//!
//! ```bash
//! cargo run --release --example heterogeneity_study
//! ```

use anyhow::Result;

use scale_fl::config::SimConfig;
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;
use scale_fl::util::stats::percentile;

fn main() -> Result<()> {
    let compute = NativeSvm::new(NativeSvm::default_dims());

    println!("heterogeneity | acc   | mean round ms | p95 ms | slowest/fastest gflops");
    for &h in &[0.0, 0.15, 0.3, 0.5, 0.8] {
        let mut cfg = SimConfig {
            n_nodes: 50,
            n_clusters: 5,
            rounds: 15,
            eval_every: 15,
            node_failure_prob: 0.05,
            node_recovery_prob: 0.6,
            seed: 21,
            ..Default::default()
        };
        cfg.fleet.heterogeneity = h;
        let cfg = cfg.normalized();
        let mut sim = Simulation::new(cfg, &compute)?;
        let report = sim.run_scale()?;

        let lat: Vec<f64> = report.rounds.iter().map(|r| r.latency_ms).collect();
        let gflops: Vec<f64> = sim.nodes.iter().map(|n| n.device.gflops).collect();
        let (lo, hi) = (
            gflops.iter().cloned().fold(f64::INFINITY, f64::min),
            gflops.iter().cloned().fold(0.0f64, f64::max),
        );
        println!(
            "{h:>13} | {:.3} | {:>13.1} | {:>6.1} | {:.1}x",
            report.final_metrics.accuracy,
            lat.iter().sum::<f64>() / lat.len() as f64,
            percentile(&lat, 95.0),
            hi / lo.max(1e-9),
        );
    }

    println!("\nLearning quality is flat (the SVM doesn't care who computes it),");
    println!("but round latency degrades with spread: synchronous HDAP rounds");
    println!("wait for the slowest member. The PI-aware clustering keeps slow");
    println!("devices together, which bounds the damage — the mechanism the");
    println!("paper's 'homogeneous environment' restriction quietly relies on.");
    Ok(())
}
