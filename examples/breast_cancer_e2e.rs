//! End-to-end driver: the paper's full experiment (§4) through the whole
//! three-layer stack.
//!
//! 100 client nodes, 10 clusters, 30 rounds on synthetic Breast Cancer
//! Wisconsin — every local training step, evaluation and aggregation
//! executes an AOT-compiled JAX/Pallas artifact via PJRT (this example
//! REQUIRES `make artifacts`). Prints the per-round loss curve, the
//! Table-1 regeneration for both SCALE and FedAvg, the Figure-2 metric
//! series, and writes `e2e_report.json`.
//!
//! ```bash
//! make artifacts && cargo run --release --example breast_cancer_e2e
//! ```

use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use scale_fl::config::SimConfig;
use scale_fl::runtime::compute::PjrtModel;
use scale_fl::runtime::manifest::ModelKind;
use scale_fl::runtime::Runtime;
use scale_fl::sim::Simulation;

fn main() -> Result<()> {
    let dir = Path::new("artifacts");
    let rt = Rc::new(
        Runtime::open(dir).context("this example needs `make artifacts` first")?,
    );
    rt.warm_up()?;
    println!("PJRT runtime up; {} artifacts compiled", rt.manifest.artifact_names().len());

    let cfg = SimConfig::paper_table1(); // 100 nodes / 10 clusters / 30 rounds
    let compute = PjrtModel::new(rt.clone(), ModelKind::Svm);

    // ---------------- SCALE ----------------
    let t0 = std::time::Instant::now();
    let mut sim = Simulation::new(cfg.clone(), &compute)?;
    let scale = sim.run_scale()?;
    let scale_wall = t0.elapsed();

    println!("\n--- SCALE loss curve (per-round mean training loss) ---");
    println!("round | loss     | updates | latency_ms | global acc");
    for r in &scale.rounds {
        println!(
            "{:>5} | {:<8.5} | {:>7} | {:>10.1} | {}",
            r.round + 1,
            r.mean_loss,
            r.updates,
            r.latency_ms,
            r.metrics.map_or("-".into(), |m| format!("{:.3}", m.accuracy)),
        );
    }

    // ---------------- FedAvg ----------------
    let t0 = std::time::Instant::now();
    let mut sim = Simulation::new(cfg.clone(), &compute)?;
    let grouping = sim.scale_grouping()?;
    let fedavg = sim.run_fedavg(Some(grouping))?;
    let fedavg_wall = t0.elapsed();

    // ---------------- Table 1 ----------------
    println!("\n--- Table 1 (paper: FedAvg 2850 updates/0.85 acc; SCALE 235/0.86) ---");
    println!("| Runs       | Nodes | Rounds | Updates | Acc | (FedAvg)");
    print!("{}", fedavg.table1_rows());
    println!("| Runs       | Nodes | Rounds | Updates | Acc | (SCALE)");
    print!("{}", scale.table1_rows());

    // ---------------- Figure 2 ----------------
    println!("\n--- Figure 2 series: FedAvg ---");
    print!("{}", fedavg.fig2_rows());
    println!("--- Figure 2 series: SCALE ---");
    print!("{}", scale.fig2_rows());

    // ---------------- headline ----------------
    let reduction = fedavg.total_updates() as f64 / scale.total_updates().max(1) as f64;
    println!("\n=== headline ===");
    println!(
        "updates   : {} -> {} ({reduction:.1}x reduction; paper ~12.1x)",
        fedavg.total_updates(),
        scale.total_updates()
    );
    println!(
        "accuracy  : {:.3} (FedAvg) vs {:.3} (SCALE); paper 0.85 vs 0.86",
        fedavg.final_metrics.accuracy, scale.final_metrics.accuracy
    );
    println!(
        "latency   : {:.0} ms vs {:.0} ms (modelled, total)",
        fedavg.total_latency_ms(),
        scale.total_latency_ms()
    );
    println!(
        "energy    : {:.1} J vs {:.1} J",
        fedavg.total_energy_j(),
        scale.total_energy_j()
    );
    println!(
        "cloud cost: ${:.6} vs ${:.6}",
        fedavg.cloud_cost_usd, scale.cloud_cost_usd
    );
    println!(
        "wall time : {:.1}s (SCALE) / {:.1}s (FedAvg) through PJRT",
        scale_wall.as_secs_f64(),
        fedavg_wall.as_secs_f64()
    );
    println!(
        "PJRT execs: train_loop={} train_step={} scores={} aggregate={}",
        rt.exec_count("svm_train_loop"),
        rt.exec_count("svm_train_step"),
        rt.exec_count("svm_scores"),
        rt.exec_count("aggregate_svm"),
    );

    // ---------------- JSON report ----------------
    let mut out = scale_fl::util::json::Value::obj();
    out.set("scale", scale.to_json());
    out.set("fedavg", fedavg.to_json());
    std::fs::write("e2e_report.json", out.to_string_pretty())?;
    println!("\nreport written to e2e_report.json");

    anyhow::ensure!(reduction > 5.0, "expected >5x update reduction, got {reduction:.1}");
    anyhow::ensure!(
        (scale.final_metrics.accuracy - fedavg.final_metrics.accuracy).abs() < 0.05,
        "accuracy gap too large"
    );
    println!("e2e OK");
    Ok(())
}
