//! Churn-stress demo: the bundled scenario drops 20% of the fleet
//! mid-run, darkens a metro, degrades the backhaul, slows stragglers and
//! drifts labels — and prints the self-regulation timeline (health
//! detection → proximity re-clustering → driver re-election) that keeps
//! the federation converging through all of it. Finishes with a
//! multi-seed sweep and checks the parallel runner is bit-identical to
//! sequential execution.
//!
//! ```bash
//! cargo run --release --example churn_stress
//! ```

use anyhow::Result;

use scale_fl::runtime::compute::NativeSvm;
use scale_fl::scenario::{self, sweep};
use scale_fl::sim::{AlgoKind, Simulation};

fn main() -> Result<()> {
    let (scenario, sim_cfg) = scenario::parse_with_sim(scenario::EXAMPLE_TOML)?;
    let cfg = sim_cfg.expect("example scenario embeds [sim]");
    println!(
        "scenario '{}': {} event(s) over {} rounds, {} nodes / {} clusters",
        scenario.name,
        scenario.events.len(),
        cfg.rounds,
        cfg.n_nodes,
        cfg.n_clusters
    );

    let compute = NativeSvm::new(NativeSvm::default_dims());
    let mut sim = Simulation::new(cfg.clone(), &compute)?;
    let report = sim.run_scale_scenario(&scenario)?;

    println!("\nround | events | reclu | elect | live | updates | acc");
    for r in &report.rounds {
        println!(
            "{:>5} | {:>6} | {:>5} | {:>5} | {:>4} | {:>7} | {}",
            r.round + 1,
            r.scenario_events,
            r.reclusterings,
            r.elections,
            r.live_nodes,
            r.updates,
            r.metrics.map_or("-".to_string(), |m| format!("{:.3}", m.accuracy)),
        );
    }

    println!("\nre-clustering timeline:");
    for n in &report.scenario {
        println!("  round {:>2}: {}", n.round + 1, n.what);
    }
    println!(
        "\nfinal: acc {:.3} | updates {} | re-clusterings {} | elections {}",
        report.final_metrics.accuracy,
        report.total_updates(),
        report.total_reclusterings(),
        report.total_elections()
    );
    assert!(report.total_reclusterings() >= 1, "expected at least one re-clustering");

    // --- multi-seed sweep: parallel must equal sequential ---
    let seeds = sweep::seeds_from(cfg.seed, 4);
    let par = sweep::run_sweep(&cfg, &scenario, &seeds, true, AlgoKind::Scale)?;
    let seq = sweep::run_sweep(&cfg, &scenario, &seeds, false, AlgoKind::Scale)?;
    for (p, s) in par.iter().zip(&seq) {
        assert_eq!(
            p.report.fingerprint(),
            s.report.fingerprint(),
            "seed {} diverged",
            p.seed
        );
    }
    let sum = sweep::summarize(&par);
    println!(
        "\nsweep over {} seeds (parallel == sequential): acc {:.3} ± {:.3}, \
         mean updates {:.1}, mean re-clusterings {:.1}",
        sum.runs, sum.mean_accuracy, sum.std_accuracy, sum.mean_updates,
        sum.mean_reclusterings
    );
    Ok(())
}
