//! Non-IID study: the paper's "identical and non-identical" data sharing
//! (§4) as a Dirichlet label-skew sweep.
//!
//! Compares SCALE and FedAvg across α ∈ {IID, 10, 1, 0.5, 0.2}: lower α =
//! stronger skew. Shows where clustered aggregation holds accuracy while
//! still cutting global updates.
//!
//! ```bash
//! cargo run --release --example noniid_study
//! ```

use anyhow::Result;

use scale_fl::config::{Partition, SimConfig};
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;

fn main() -> Result<()> {
    let compute = NativeSvm::new(NativeSvm::default_dims());

    println!("partition  | SCALE acc / updates | FedAvg acc / updates | reduction");
    for (label, partition) in [
        ("iid", Partition::Iid),
        ("α=10", Partition::LabelSkew(10.0)),
        ("α=1.0", Partition::LabelSkew(1.0)),
        ("α=0.5", Partition::LabelSkew(0.5)),
        ("α=0.2", Partition::LabelSkew(0.2)),
    ] {
        let cfg = SimConfig {
            n_nodes: 50,
            n_clusters: 5,
            rounds: 20,
            partition,
            eval_every: 20,
            seed: 3,
            ..Default::default()
        }
        .normalized();

        let mut sim = Simulation::new(cfg.clone(), &compute)?;
        let scale = sim.run_scale()?;
        let mut sim = Simulation::new(cfg, &compute)?;
        let fedavg = sim.run_fedavg(None)?;

        println!(
            "{:<10} |   {:.3} / {:>7}   |   {:.3} / {:>8}   | {:>6.1}x",
            label,
            scale.final_metrics.accuracy,
            scale.total_updates(),
            fedavg.final_metrics.accuracy,
            fedavg.total_updates(),
            fedavg.total_updates() as f64 / scale.total_updates().max(1) as f64,
        );
    }

    println!("\nClustered aggregation matches the FedAvg baseline at every");
    println!("skew level while holding the ~10x global-update reduction —");
    println!("the linear SVM on (near-)separable WDBC is robust to label skew.");
    Ok(())
}
